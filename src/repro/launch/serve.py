"""Serving launcher: prefill + batched greedy decode on local devices.

Demonstrates the inference path end-to-end (reduced configs on CPU): batch of
prompts -> prefill builds the ring-buffer KV caches / recurrent states ->
token-by-token decode.  The same ``decode_step`` is what the dry-run lowers
at production shapes.  ``--retention`` serves an AdaptCL-reconfigured
sub-model (capability-adapted serving).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import transformer as T
from repro.models.config import apply_retention

__all__ = ["serve_batch", "main"]


def serve_batch(cfg, params, prompts: jnp.ndarray, new_tokens: int = 16,
                extra_batch=None):
    """prompts [b, s] -> generated [b, new_tokens] (greedy)."""
    b, s = prompts.shape
    batch = {"tokens": prompts}
    if extra_batch:
        batch.update(extra_batch)
    decode = jax.jit(lambda p, st, tok: T.decode_step(p, cfg, st, tok))
    logits, state = T.prefill(params, cfg, batch, max_len=s + new_tokens)
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for _ in range(new_tokens):
        out.append(tok)
        logits, state = decode(params, state, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--retention", type=float, default=1.0)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.retention < 1.0:
        cfg = apply_retention(cfg, args.retention)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    extra = {}
    if cfg.num_prefix_embeds:
        extra["prefix_embeds"] = jnp.zeros((args.batch, cfg.num_prefix_embeds, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.encoder_layers:
        extra["enc_embeds"] = jnp.zeros((args.batch, 16, cfg.d_model), jnp.dtype(cfg.dtype))
    t0 = time.perf_counter()
    gen = serve_batch(cfg, params, prompts, args.new_tokens, extra)
    dt = time.perf_counter() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"[serve] {cfg.name} retention={cfg.retention}: generated {gen.shape} "
          f"in {dt:.2f}s ({tps:.1f} tok/s); sample: {np.asarray(gen[0])[:8]}")
    assert np.isfinite(np.asarray(gen)).all()


if __name__ == "__main__":
    main()
