"""Training launcher: real-execution loop on local devices (CPU/TPU) +
AdaptCL-driven reconfiguration between pruning intervals.

On this container it trains reduced configs for real (examples/quickstart.py);
on a TPU fleet the same entry point drives full configs — mesh shape and
shardings come from the same rules the dry-run validates.

Collaborative mode (``--workers N``) runs the paper's Algorithm 1 at
transformer scale: N simulated workers share a base model; each trains its
reconfigured sub-model for E local steps per round; the server aggregates
By-worker and learns pruned rates from the Eq. 6 channel model.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data.synthetic import SyntheticLMTask
from repro.models import transformer as T
from repro.models.config import ModelConfig, apply_retention, param_count
from repro.optim.optimizers import adamw, apply_updates

__all__ = ["train_loop", "main"]


def make_train_step(cfg: ModelConfig, opt):
    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: T.lm_loss(p, cfg, batch))(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    return step


def train_loop(
    cfg: ModelConfig,
    steps: int = 100,
    batch: int = 8,
    lr: float = 3e-4,
    seed: int = 0,
    log_every: int = 10,
):
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)
    opt = adamw(lr)
    opt_state = opt.init(params)
    step_fn = make_train_step(cfg, opt)
    task = SyntheticLMTask(vocab_size=cfg.vocab_size, seq_len=64, seed=seed)
    rng = np.random.default_rng(seed)
    losses = []
    t0 = time.perf_counter()
    for i in range(steps):
        toks = jnp.asarray(task.sample(batch, rng))
        b = {"tokens": toks}
        if cfg.num_prefix_embeds:
            b["prefix_embeds"] = jnp.zeros((batch, cfg.num_prefix_embeds, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.encoder_layers:
            b["enc_embeds"] = jnp.zeros((batch, 16, cfg.d_model), jnp.dtype(cfg.dtype))
        params, opt_state, loss = step_fn(params, opt_state, b)
        losses.append(float(loss))
        if log_every and (i + 1) % log_every == 0:
            print(f"step {i+1:4d} loss {np.mean(losses[-log_every:]):.4f}")
    dt = time.perf_counter() - t0
    return params, losses, dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--retention", type=float, default=1.0)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.retention < 1.0:
        cfg = apply_retention(cfg, args.retention)
    print(f"[train] {cfg.name} retention={cfg.retention} params={param_count(cfg):,}")
    params, losses, dt = train_loop(cfg, args.steps, args.batch, args.lr)
    print(f"[train] {args.steps} steps in {dt:.1f}s; loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert np.isfinite(losses[-1])


if __name__ == "__main__":
    main()
