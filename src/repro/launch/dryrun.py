import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST run before any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) pair.

For each pair this produces the *production artifact*: the scanned,
remat'd, q-blocked step function, jitted with rule-derived shardings, lowered
and compiled against the 256-chip (16x16) or 512-chip (2x16x16) mesh of host
placeholder devices.  ``compiled.memory_analysis()`` proves the memory fit;
``compiled.cost_analysis()`` + the HLO collective listing feed §Roofline.

AdaptCL hook: ``--retention g`` reconfigures the model to the gamma-g
sub-model (NetworkReconfigure at production scale) before lowering, so the
roofline table can show how each term scales with pruning.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import dataclasses
import json
import re
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.config import ModelConfig, apply_retention, flops_per_token, param_count
from repro.optim.optimizers import adamw
from repro.sharding.specs import batch_pspecs, decode_state_pspecs, param_pspec, shard_tree

__all__ = [
    "input_specs",
    "make_step",
    "lower_pair",
    "run_pair",
    "long_500k_eligible",
    "prepare_config",
    "collective_bytes_from_hlo",
]

WHISPER_ENC_FRAMES = 1500  # 30 s of audio at 50 Hz post-conv (Whisper native)


def long_500k_eligible(cfg: ModelConfig, variant: Optional[str]) -> bool:
    """Sub-quadratic rule (DESIGN.md §5): every layer must be windowed or
    recurrent at decode time."""
    kinds = set(cfg.layer_kinds())
    if cfg.encoder_layers:
        return False  # enc-dec cross attention over full encoder output
    if variant == "windowed":
        return True
    return kinds <= {"rglru", "local", "mlstm", "slstm", "moe_local"}


def prepare_config(
    arch: str,
    shape_name: str,
    *,
    retention: float = 1.0,
    variant: Optional[str] = None,
    dtype: str = "bfloat16",
    scan_layers: bool = True,
    remat: bool = True,
    q_block: Optional[int] = 1024,
    num_layers: Optional[int] = None,
    seq_shard: bool = False,
) -> ModelConfig:
    cfg = get_config(arch)
    if variant == "windowed":
        pattern = tuple(
            {"attn": "local", "moe": "moe_local"}.get(k, k) for k in cfg.block_pattern
        )
        cfg = cfg.replace(block_pattern=pattern,
                          window_size=cfg.window_size or 4096)
    if retention < 1.0:
        cfg = apply_retention(cfg, retention)
    kw: Dict[str, Any] = dict(dtype=dtype, scan_layers=scan_layers, remat=remat,
                              attn_q_block=q_block,
                              seq_shard_activations=seq_shard)
    if num_layers is not None:
        kw["num_layers"] = num_layers
    if shape_name == "decode_32k" and cfg.pos_embed == "learned":
        kw["max_position"] = 32_768 + 8
    # pad vocab to a model-axis-shardable multiple (logits masked above real)
    if cfg.vocab_size % 256 != 0:
        kw["vocab_size_real"] = cfg.vocab_size
        kw["vocab_size"] = ((cfg.vocab_size + 255) // 256) * 256
    return cfg.replace(**kw)


def input_specs(cfg: ModelConfig, shape_name: str, mesh) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (weak-type-correct, sharded, no allocation)
    for every input of the step function selected by the shape kind."""
    shp = SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len

    def sds(shape, dtype, pspec):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, pspec))

    if shp.kind in ("train", "prefill"):
        n_prefix = cfg.num_prefix_embeds
        s_text = S - n_prefix
        batch = {"tokens": sds((B, s_text), jnp.int32, batch_pspecs("tokens", (B, s_text), mesh))}
        if n_prefix:
            batch["prefix_embeds"] = sds(
                (B, n_prefix, cfg.d_model), jnp.dtype(cfg.dtype),
                batch_pspecs("prefix_embeds", (B, n_prefix, cfg.d_model), mesh),
            )
        if cfg.encoder_layers:
            batch["enc_embeds"] = sds(
                (B, WHISPER_ENC_FRAMES, cfg.d_model), jnp.dtype(cfg.dtype),
                batch_pspecs("enc_embeds", (B, WHISPER_ENC_FRAMES, cfg.d_model), mesh),
            )
        return {"batch": batch}

    # decode: one token against a cache of S
    enc_len = WHISPER_ENC_FRAMES if cfg.encoder_layers else 0
    state_shapes = jax.eval_shape(
        lambda: T.init_decode_state(cfg, B, S, enc_len=enc_len)
    )
    state = shard_tree(state_shapes, mesh, decode_state_pspecs)
    token = sds((B,), jnp.int32, batch_pspecs("token", (B,), mesh))
    return {"state": state, "token": token}


def make_step(cfg: ModelConfig, shape_name: str, optimizer="adamw",
              opt_dtype="float32", microbatch: int = 1):
    """Returns (step_fn, abstract_args_builder(mesh) -> tuple of SDS).

    ``opt_dtype="bfloat16"`` halves Adam m/v memory (a §Perf lever for the
    400B config); ``microbatch=k`` splits the global batch into k sequential
    gradient-accumulation chunks (k-fold smaller activations).
    """
    shp = SHAPES[shape_name]
    opt = adamw(3e-4, state_dtype=jnp.dtype(opt_dtype))

    if shp.kind == "train":
        def train_step(params, opt_state, batch):
            if microbatch > 1:
                def one(i):
                    mb = jax.tree.map(
                        lambda x: x.reshape(microbatch, x.shape[0] // microbatch, *x.shape[1:])[i],
                        batch,
                    )
                    return jax.value_and_grad(lambda p: T.lm_loss(p, cfg, mb))(params)

                def body(carry, i):
                    loss_acc, grad_acc = carry
                    loss, grads = one(i)
                    return (loss_acc + loss,
                            jax.tree.map(jnp.add, grad_acc, grads)), None

                zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    body, (jnp.zeros((), jnp.float32), zero), jnp.arange(microbatch)
                )
                loss = loss / microbatch
                grads = jax.tree.map(lambda g: g / microbatch, grads)
            else:
                loss, grads = jax.value_and_grad(lambda p: T.lm_loss(p, cfg, batch))(params)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
            return params, opt_state, loss

        def abstract_args(mesh):
            p_sds = jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))
            p_sds = shard_tree(p_sds, mesh, param_pspec)
            o_sds = jax.eval_shape(opt.init, p_sds)
            o_sds = shard_tree(o_sds, mesh, lambda path, shp_, m: param_pspec(path.split("/", 1)[-1] if "/" in path else path, shp_, m))
            specs = input_specs(cfg, shape_name, mesh)
            return (p_sds, o_sds, specs["batch"])

        return train_step, abstract_args

    if shp.kind == "prefill":
        def prefill_step(params, batch):
            return T.prefill(params, cfg, batch, max_len=SHAPES[shape_name].seq_len)

        def abstract_args(mesh):
            p_sds = shard_tree(
                jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg)), mesh, param_pspec
            )
            return (p_sds, input_specs(cfg, shape_name, mesh)["batch"])

        return prefill_step, abstract_args

    def serve_step(params, state, token):
        return T.decode_step(params, cfg, state, token)

    def abstract_args(mesh):
        p_sds = shard_tree(
            jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg)), mesh, param_pspec
        )
        specs = input_specs(cfg, shape_name, mesh)
        return (p_sds, specs["state"], specs["token"])

    return serve_step, abstract_args


_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|u8|u16|u32|u64|s8|s16|s32|s64|pred)\[([\d,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "u8": 1, "s8": 1, "u16": 2,
          "s16": 2, "u32": 4, "s32": 4, "u64": 8, "s64": 8, "pred": 1}
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in the HLO (per device)."""
    out = {op: 0.0 for op in _COLL_OPS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(%?[\w.\-]+)\s*=\s*(.*)$", stripped)
        if not m:
            continue
        rhs = m.group(2)
        for op in _COLL_OPS:
            # match the op as the instruction name: "<type> all-reduce(" etc.
            if re.search(rf"\s{op}(-start|-done)?\(", rhs) or rhs.startswith(f"{op}("):
                head = rhs.split(f" {op}", 1)[0]
                nbytes = 0.0
                for dt, dims in _SHAPE_RE.findall(head):
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    nbytes += n * _BYTES[dt]
                out[op] += nbytes
                out["count"] += 1
                break
    out["total"] = sum(out[op] for op in _COLL_OPS)
    return out


def lower_pair(cfg: ModelConfig, shape_name: str, mesh, **step_kw):
    """Lower + compile; returns (compiled, lowered, elapsed seconds)."""
    step, abstract_args = make_step(cfg, shape_name, **step_kw)
    args = abstract_args(mesh)
    t0 = time.perf_counter()
    with mesh:
        lowered = jax.jit(step).lower(*args)
        compiled = lowered.compile()
    return compiled, lowered, time.perf_counter() - t0


def run_pair(
    arch: str,
    shape_name: str,
    mesh_kind: str = "single",
    *,
    retention: float = 1.0,
    variant: Optional[str] = None,
    verbose: bool = True,
) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    cfg = prepare_config(arch, shape_name, retention=retention, variant=variant)
    if shape_name == "long_500k" and not long_500k_eligible(cfg, variant):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": "quadratic attention at 500k (DESIGN.md §5)"}
    compiled, lowered, dt = lower_pair(cfg, shape_name, mesh)
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "devices": n_dev,
        "retention": retention,
        "variant": variant,
        "status": "ok",
        "compile_s": round(dt, 2),
        "params": param_count(cfg),
        "model_flops_per_token": flops_per_token(cfg, SHAPES[shape_name].seq_len),
        "hlo_flops_per_device": float(cost.get("flops", 0.0)),
        "hlo_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
    }
    if verbose:
        print(
            f"[dryrun] {arch} x {shape_name} x {mesh_kind}({n_dev}) "
            f"retention={retention} compile={dt:.1f}s "
            f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
            f"args={rec['memory']['argument_bytes']/2**30:.2f}GiB "
            f"flops/dev={rec['hlo_flops_per_device']:.3g} "
            f"coll={coll['total']/2**20:.1f}MiB/{int(coll['count'])}ops"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--retention", type=float, default=1.0)
    ap.add_argument("--variant", default=None, choices=[None, "windowed"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="JSONL output path")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]

    records = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                variant = args.variant
                if shape == "long_500k" and arch == "granite-moe-1b-a400m" and variant is None:
                    variant = "windowed"  # demonstrate the dense windowed variant
                try:
                    rec = run_pair(arch, shape, mesh_kind,
                                   retention=args.retention, variant=variant)
                except Exception as e:  # a failure here is a sharding bug
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                    print(f"[dryrun] FAILED {arch} x {shape} x {mesh_kind}: {e}")
                records.append(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_fail = len(records) - n_ok - n_skip
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
