import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
# ^ before any jax import (same contract as dryrun.py).

"""Roofline analysis from compiled dry-run artifacts (TPU v5e terms).

Per (arch x shape x mesh) this derives the three roofline terms:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_link_bw

**Scan correction.** The production artifact drives layers with ``lax.scan``,
whose body XLA cost analysis counts ONCE (verified empirically: an 8-layer
scan reports 1/8 the unrolled FLOPs).  We therefore lower two additional
*cost artifacts* with layers unrolled at depth = 1 and 2 pattern periods and
extrapolate:

    per_period = cost(2 periods) - cost(1 period)
    outer      = cost(1 period)  - per_period        (embedding, head, loss)
    total      = outer + (num_layers / period) * per_period

All sequence-level recurrences are associative scans (log-depth combinator
trees, no while loops), so this single-level correction is exact in loop
structure; the cost artifacts disable q-blocking (same FLOPs, no inner scan)
and keep remat so recompute FLOPs are counted, matching production.

Memory fit comes from the production artifact's ``memory_analysis()`` (the
cost artifacts are never meant to fit — they only exist to be counted).
"""
import argparse
import json
import math
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.configs import SHAPES, get_config, list_archs
from repro.launch.dryrun import (
    collective_bytes_from_hlo,
    long_500k_eligible,
    lower_pair,
    prepare_config,
)
from repro.launch.mesh import HARDWARE, make_production_mesh
from repro.models.config import flops_per_token, param_count

__all__ = ["analyze_pair", "roofline_terms"]


def _cost_record(cfg, shape_name, mesh, **step_kw) -> Dict[str, float]:
    compiled, lowered, dt = lower_pair(cfg, shape_name, mesh, **step_kw)
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["total"]),
        "coll_ops": {k: v for k, v in coll.items() if isinstance(v, float) and v > 0},
        "temp_bytes": float(mem.temp_size_in_bytes),
        "arg_bytes": float(mem.argument_size_in_bytes),
        "compile_s": dt,
    }


def analyze_pair(
    arch: str,
    shape_name: str,
    mesh_kind: str = "single",
    *,
    retention: float = 1.0,
    variant: Optional[str] = None,
    seq_shard: bool = False,
    label: str = "baseline",
    opt_dtype: str = "float32",
    microbatch: int = 1,
    full_dp: bool = False,
) -> Dict[str, Any]:
    from repro.sharding import specs as _specs

    _specs.FULL_DP = full_dp
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = int(np.prod(list(mesh.shape.values())))
    cfg = prepare_config(arch, shape_name, retention=retention, variant=variant,
                         seq_shard=seq_shard)
    if shape_name == "long_500k" and not long_500k_eligible(cfg, variant):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "label": label, "status": "skipped",
                "reason": "quadratic attention at 500k (DESIGN.md §5)"}

    period = len(cfg.block_pattern)
    G_total = cfg.num_layers / period

    step_kw = dict(opt_dtype=opt_dtype, microbatch=microbatch)
    prod = _cost_record(cfg, shape_name, mesh, **step_kw)

    def reduced(k_periods):
        return prepare_config(
            arch, shape_name, retention=retention, variant=variant,
            seq_shard=seq_shard, scan_layers=False, q_block=None,
            num_layers=k_periods * period,
        )

    c1 = _cost_record(reduced(1), shape_name, mesh, **step_kw)
    c2 = _cost_record(reduced(2), shape_name, mesh, **step_kw)

    def extrap(key):
        per = max(c2[key] - c1[key], 0.0)
        outer = max(c1[key] - per, 0.0)
        return outer + G_total * per

    # the gradient-accumulation loop is itself a lax.scan (body counted once
    # by XLA cost analysis) -> scale the extrapolated terms by microbatch;
    # memory_analysis (temp/args) needs no correction.
    flops_dev = extrap("flops") * microbatch
    bytes_dev = extrap("bytes") * microbatch
    coll_dev = extrap("coll") * microbatch

    hw = HARDWARE
    t_compute = flops_dev / hw["peak_flops_bf16"]
    t_memory = bytes_dev / hw["hbm_bandwidth"]
    t_coll = coll_dev / hw["ici_bandwidth"]
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]

    shp = SHAPES[shape_name]
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        mf_tok = flops_per_token(cfg, shp.seq_len)           # 6N(+attn)
    elif shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        mf_tok = flops_per_token(cfg, shp.seq_len) / 3.0     # fwd only: 2N
    else:  # decode: one token per sequence against a cache of seq_len
        tokens = shp.global_batch
        mf_tok = flops_per_token(cfg, shp.seq_len) / 3.0
    model_flops_dev = mf_tok * tokens / n_dev
    useful = model_flops_dev / flops_dev if flops_dev else float("nan")

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "label": label,
        "status": "ok",
        "retention": retention,
        "variant": variant,
        "seq_shard": seq_shard,
        "opt_dtype": opt_dtype,
        "microbatch": microbatch,
        "full_dp": full_dp,
        "devices": n_dev,
        "params": param_count(cfg),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collective_schedule": prod["coll_ops"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": model_flops_dev,
        "useful_flops_ratio": useful,
        "temp_bytes": prod["temp_bytes"],
        "arg_bytes": prod["arg_bytes"],
        "fits_hbm": (prod["temp_bytes"] + prod["arg_bytes"]) <= hw["hbm_bytes"],
        "compile_s": prod["compile_s"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--retention", type=float, default=1.0)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--label", default="baseline")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape in shapes:
            variant = "windowed" if (shape == "long_500k" and arch == "granite-moe-1b-a400m") else None
            try:
                rec = analyze_pair(arch, shape, args.mesh, retention=args.retention,
                                   seq_shard=args.seq_shard, variant=variant,
                                   label=args.label)
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "mesh": args.mesh,
                       "label": args.label, "status": "FAILED",
                       "error": f"{type(e).__name__}: {e}"}
            if rec["status"] == "ok":
                print(f"[roofline] {arch} x {shape}: dominant={rec['dominant']} "
                      f"tc={rec['t_compute_s']*1e3:.1f}ms tm={rec['t_memory_s']*1e3:.1f}ms "
                      f"tx={rec['t_collective_s']*1e3:.1f}ms useful={rec['useful_flops_ratio']:.2f} "
                      f"fits={rec['fits_hbm']}")
            else:
                print(f"[roofline] {arch} x {shape}: {rec['status']} {rec.get('reason', rec.get('error',''))[:100]}")
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
