"""Production mesh construction (TPU v5e target).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — `pod` is the
cross-pod data-parallel axis (DCN-connected in a real deployment).

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_local_mesh",
    "make_fleet_mesh",
    "HARDWARE",
]

# TPU v5e hardware constants used by the roofline analysis (per chip).
HARDWARE = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bandwidth": 819e9,      # bytes/s
    "ici_bandwidth": 50e9,       # bytes/s per link
    "hbm_bytes": 16 * 2**30,     # 16 GiB
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests on CPU)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(n_dev: int | None = None, axis: str = "fleet"):
    """1-D worker-shard mesh for the resident fleet (``SimConfig.mesh``).

    ``n_dev`` defaults to every visible device; on CPU use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before any
    jax import) to get virtual devices.  The simulator shards its
    ``[W, ...]`` stacks over ``axis`` as ``W = n_dev x W_local``."""
    avail = len(jax.devices())
    if n_dev is None:
        n_dev = avail
    if n_dev > avail:
        raise ValueError(f"requested {n_dev} devices, only {avail} visible")
    return jax.make_mesh((n_dev,), (axis,))
