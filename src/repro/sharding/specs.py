"""Sharding rules: parameter / optimizer / batch / decode-state partitioning.

Scheme ("FSDP + TP", MaxText-style):
  * ``model`` axis — tensor parallelism: attention heads (KV groups), FFN
    columns, experts, recurrent channels, vocab.
  * ``data`` axis — batch parallelism for activations AND fully-sharded
    parameters/optimizer state over d_model-like dims (ZeRO-3), so nothing is
    replicated 16x.
  * ``pod`` axis (multi-pod) — pure data parallelism across pods: batch
    shards over (pod, data); parameters are replicated across pods (gradient
    all-reduce crosses the inter-pod links — visible in the HLO).

Every rule is divisibility-checked with fallbacks (e.g. kv_heads=8 cannot
split 16-way -> shard head_dim instead; odd vocab -> shard d_model).  AdaptCL
interaction: reconfigured sub-models shrink unit dims; `apply_retention`
snaps dims to sharding-friendly multiples so the same rules keep applying.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_pspec",
    "shard_tree",
    "batch_pspecs",
    "decode_state_pspecs",
    "tree_pspecs",
    "constrain",
    "current_mesh",
    "fleet_pspec",
    "fleet_sharding",
]


def fleet_pspec(axis: str = "fleet") -> P:
    """PartitionSpec for the resident fleet's ``[W, ...]`` stacks: the worker
    dimension shards over the ``axis`` mesh axis, everything downstream of it
    stays replicated (each device holds W_local full-model rows).  A spec
    shorter than the array rank replicates the remaining dims, so ONE spec
    covers params / masks / momentum / data stacks of any rank."""
    return P(axis)


def fleet_sharding(mesh: Mesh, axis: str = "fleet") -> NamedSharding:
    """NamedSharding placing ``[W, ...]`` stacks row-sharded over ``axis`` —
    what makes ``core.fleet.FleetState`` sharding-agnostic: ``init_state``
    takes this (or None for today's single-device layout) and nothing else
    about the state changes."""
    if axis not in mesh.shape:
        raise ValueError(
            f"mesh axes {tuple(mesh.shape)} have no fleet axis {axis!r}"
        )
    return NamedSharding(mesh, fleet_pspec(axis))


def current_mesh():
    """Mesh from the active `with mesh:` context, or None (smoke tests)."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def constrain(x, prefs):
    """with_sharding_constraint via role prefs [(dim, "batch"|"model")].

    No-op outside a mesh context; divisibility-checked per dim (e.g. 4 heads
    never constrain onto a 16-way model axis).  This is what pins activations
    to batch sharding so GSPMD gathers FSDP weights instead of resharding
    activations (see EXPERIMENTS.md §Perf iteration 1).
    """
    mesh = current_mesh()
    if mesh is None or "data" not in mesh.shape or "model" not in mesh.shape:
        return x
    ba = _batch_axes(mesh)
    resolved = [(d, ba if a == "batch" else a) for d, a in prefs]
    spec = _assign(x.shape, mesh, resolved)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def _fits(shape, dim: int, mesh: Mesh, axis) -> bool:
    return dim < len(shape) and shape[dim] % _axis_size(mesh, axis) == 0


def _assign(shape, mesh: Mesh, prefs) -> P:
    """prefs: list of (dim, mesh_axis) tried in order; one mesh axis used once."""
    spec = [None] * len(shape)
    used = set()
    for dim, axis in prefs:
        if dim < 0:
            dim = len(shape) + dim
        key = axis if not isinstance(axis, tuple) else axis
        flat = axis if isinstance(axis, tuple) else (axis,)
        if any(a in used for a in flat):
            continue
        if dim >= len(shape) or spec[dim] is not None:
            continue
        if _fits(shape, dim, mesh, axis):
            spec[dim] = axis
            used.update(flat)
    return P(*spec)


# rules keyed by the last path component (parameter leaf name); `stk` = True
# when the leaf has a leading stacked-layers axis (blocks/...), shifting dims.
def param_pspec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    has_model = "model" in mesh.shape
    has_data = "data" in mesh.shape
    if not (has_model and has_data):
        return P()
    name = path.split("/")[-1]
    stk = 1 if (("blocks" in path or "enc_blocks" in path) and len(shape) > 0) else 0
    if FULL_DP:
        # pure-DP: ZeRO-3 over the combined (data, model) axes, largest dim
        fsdp = tuple(a for a in ("data", "model") if a in mesh.shape)
        dims = sorted(range(stk, len(shape)), key=lambda d: -shape[d])
        return _assign(shape, mesh, [(d, fsdp) for d in dims])

    def A(*prefs):
        return _assign(shape, mesh, [(d + stk if d >= 0 else d, a) for d, a in prefs])

    if name in ("wq",):          # [D, H, hd]
        return A((1, "model"), (2, "model"), (0, "data"))
    if name in ("wk", "wv"):     # [D, KV, hd] — never split hd (rope splits
        # it in half); replicate KV over model when kv doesn't divide.
        return A((1, "model"), (0, "data"))
    if name == "wo":             # [H, hd, D]
        return A((0, "model"), (1, "model"), (2, "data"))
    if name == "bq":             # [H, hd]
        return A((0, "model"))
    if name in ("bk", "bv"):
        return A((0, "model"))
    if name in ("w_up", "w_gate", "ws_up", "ws_gate"):
        if len(shape) - stk == 3:   # moe [E, D, F]
            return A((0, "model"), (1, "data"))
        return A((1, "model"), (0, "data"))      # [D, F]
    if name in ("w_down", "ws_down"):
        if len(shape) - stk == 3:   # moe [E, F, D]
            return A((0, "model"), (1, "data"))
        return A((0, "model"), (1, "data"))      # [F, D]
    if name == "w_router":       # [D, E]
        return A((1, "model"), (0, "data"))
    if name in ("w_y", "w_x"):   # rglru [D, R]
        return A((1, "model"), (0, "data"))
    if name == "w_out":          # rglru [R, D]
        return A((0, "model"), (1, "data"))
    if name == "conv":           # [w, R]
        return A((1, "model"))
    if name in ("gate_a", "gate_x"):  # [H, hw, hw]
        return A((0, "model"))
    if name == "lam":            # [R]
        return A((0, "model"))
    if name in ("w_z", "w_i", "w_f", "w_o"):  # xlstm [DI, DI] or [DI, H]
        return A((1, "model"), (0, "data"))
    if name == "embed":          # [V, D]
        return A((0, "model"), (1, "data"))
    if name == "lm_head":        # [D, V]
        return A((1, "model"), (0, "data"))
    if name in ("pos_embed", "enc_pos"):  # [T, D]
        return A((0, "model"), (1, "data"))
    # norms scale/bias, b_f, b_i and anything tiny: replicate
    return P()


def tree_pspecs(tree, mesh: Mesh, pspec_fn) -> Any:
    def walk(path_parts, node):
        if isinstance(node, dict):
            return {k: walk(path_parts + [str(k)], v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(path_parts + [str(i)], v) for i, v in enumerate(node)]
            return type(node)(t) if isinstance(node, tuple) else t
        path = "/".join(path_parts)
        return pspec_fn(path, tuple(np.shape(node) if hasattr(node, "shape") else ()), mesh)

    return walk([], tree)


def shard_tree(tree, mesh: Mesh, pspec_fn=param_pspec):
    """SDS tree -> SDS tree with NamedShardings attached."""
    specs = tree_pspecs(tree, mesh, pspec_fn)
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=NamedSharding(mesh, s)),
        tree, specs,
    )


# Full-DP mode: small models whose head layout defeats tensor parallelism
# (e.g. xlstm-1.3b: 4 heads vs a 16-way model axis) run pure data parallelism:
# batch shards over BOTH axes and params are FSDP over the combined axes.
FULL_DP = False


def _batch_axes(mesh: Mesh):
    if FULL_DP:
        return tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_pspecs(path: str, shape, mesh: Mesh) -> P:
    """Inputs: tokens/labels [B, S]; prefix/enc embeds [B, N, D]."""
    ba = _batch_axes(mesh)
    if not shape:
        return P()
    if shape[0] % _axis_size(mesh, ba) == 0:
        return P(ba, *([None] * (len(shape) - 1)))
    # batch too small (long_500k b=1): shard sequence instead where possible
    if len(shape) >= 2 and shape[1] % _axis_size(mesh, ba) == 0:
        return P(None, ba, *([None] * (len(shape) - 2)))
    return P()


def decode_state_pspecs(path: str, shape, mesh: Mesh) -> P:
    """KV caches [G, B, L, KV, hd]; recurrent states [G, B, ...]."""
    ba = _batch_axes(mesh)
    name = path.split("/")[-1]
    stk = 1 if "blocks" in path else 0

    def A(*prefs):
        return _assign(shape, mesh, [(d + stk, a) for d, a in prefs])

    if name in ("k", "v"):        # [B, L, KV, hd]
        return A((0, ba), (2, "model"), (3, "model"), (1, ba))
    if name in ("cross_k", "cross_v"):
        return A((0, ba), (2, "model"), (3, "model"))
    if name == "pos":             # [B, L]
        return A((0, ba), (1, ba))
    if name == "h":               # rglru [B, R]
        return A((0, ba), (1, "model"))
    if name == "conv":            # [B, w-1, R]
        return A((0, ba), (2, "model"))
    if name == "C":               # mlstm [B, H, hd, hd]
        return A((0, ba), (1, "model"), (2, "model"))
    if name in ("n", "m"):        # [B, H, hd] / [B, H]
        return A((0, ba), (1, "model"), (2, "model"))
    if name in ("c",):            # slstm [B, DI]
        return A((0, ba), (1, "model"))
    return P()
