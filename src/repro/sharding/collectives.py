"""On-mesh collectives for the sharded fleet beyond ``psum``.

The two-tier aggregation path (``aggregation.aggregate_by_worker_stacked_jnp``
with ``axis=``) only ever needed an all-reduce: per-shard partial sums close
with one ``lax.psum``.  Cross-shard ORDER STATISTICS — the robust layer's
coordinate-wise trimmed mean, and the health tracker's fleet-wide median/MAD
— cannot be expressed as a sum: every shard needs every vote.  This module
grows the ``all_gather``-along-``fleet`` path for them.

:func:`all_gather_fleet` gathers ``[W_local, ...]`` row blocks into full
``[W, ...]`` stacks, tiled along axis 0 in mesh-axis-index order — exactly
the contiguous slot layout the fleet shards by (shard ``s`` owns slots
``[s * W_local, (s+1) * W_local)``), so the gathered stack's row ``w`` IS
global slot ``w``.  On the degenerate 1-device mesh the gather concatenates
a single block: bit-identical to no-mesh, which is what lets the robust
bench pin ``mesh((1,)) == no-mesh`` exactly.

:func:`shard_row_slice` is the inverse projection: slice the local
``W_local`` row block (or weight-vector segment) back out of a replicated
full-fleet array, using the same slot algebra.
"""
from __future__ import annotations

from typing import Any

import jax
from jax import lax

__all__ = ["all_gather_fleet", "shard_row_slice"]


def all_gather_fleet(tree: Any, axis: str = "fleet") -> Any:
    """Gather each leaf's sharded leading (worker) axis into the full fleet.

    Must run inside a ``shard_map`` body over a mesh with ``axis``.  Leaves
    are ``[W_local, ...]`` row blocks; the result's leaves are ``[W, ...]``
    with ``W = n_dev * W_local``, tiled in shard order and replicated across
    the axis."""
    return jax.tree.map(
        lambda x: lax.all_gather(x, axis, axis=0, tiled=True), tree
    )


def shard_row_slice(full: Any, w_local: int, axis: str = "fleet") -> Any:
    """Slice this shard's ``[W_local, ...]`` row block out of full-fleet
    leaves — the inverse of :func:`all_gather_fleet` under the contiguous
    slot layout.  Must run inside a ``shard_map`` body."""
    start = lax.axis_index(axis) * w_local
    return jax.tree.map(
        lambda x: lax.dynamic_slice_in_dim(x, start, w_local, 0), full
    )
