"""Version-compat shims for the sharding APIs we use.

``shard_map`` moved out of ``jax.experimental`` (and its replication-check
kwarg was renamed ``check_rep`` -> ``check_vma``) across the jax versions
this repo runs on.  The dance lives HERE once — ``core.collab`` (workers as
data-axis slices) and the mesh-sharded fleet path (``core.fused``) both
import :func:`shard_map_compat` instead of inlining the probe.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map_compat"]

if hasattr(jax, "shard_map"):
    _shard_map, _CHECK_KW = jax.shard_map, "check_vma"
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` across jax versions, with the replication check under
    one boolean (``check_vma`` on current jax, ``check_rep`` on <= 0.4.x).
    Defaults to False: our bodies close replicated globals over ``psum``
    collectives, which the strict checker rejects on older versions."""
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: check},
    )
