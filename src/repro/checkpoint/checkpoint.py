"""Checkpointing: pytree <-> .npz with global-index + config metadata.

AdaptCL checkpoints carry the worker's global index I_w (unit ids per layer)
so a restored sub-model can be re-embedded into base coordinates; the server
checkpoint carries the CIG importance order so pruning stays Constant across
restarts (the paper's principle would silently break if the order were
recomputed after a restart — this is load-bearing, and tested).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint"]

_SEP = "::"


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}{_SEP}"))
    else:
        out[prefix[: -len(_SEP)]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(k.startswith("#") for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(
    path: str,
    params,
    *,
    step: int = 0,
    global_index: Optional[Dict[str, np.ndarray]] = None,
    importance_order: Optional[Dict[str, np.ndarray]] = None,
    meta: Optional[Dict[str, Any]] = None,
) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(jax.tree.map(np.asarray, params))
    payload = {f"param{_SEP}{k}": v for k, v in flat.items()}
    if global_index:
        payload.update({f"gidx{_SEP}{k}": np.asarray(v) for k, v in global_index.items()})
    if importance_order:
        payload.update({f"order{_SEP}{k}": np.asarray(v) for k, v in importance_order.items()})
    payload["__meta__"] = np.frombuffer(
        json.dumps({"step": step, **(meta or {})}).encode(), dtype=np.uint8
    )
    np.savez(path, **payload)


def load_checkpoint(path: str) -> Tuple[Any, Dict[str, Any]]:
    """Returns (params, extras) where extras has step/global_index/order/meta."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    z = np.load(path, allow_pickle=False)
    flat_params, gidx, order = {}, {}, {}
    meta: Dict[str, Any] = {}
    for key in z.files:
        if key == "__meta__":
            meta = json.loads(z[key].tobytes().decode())
        elif key.startswith(f"param{_SEP}"):
            flat_params[key[len(f"param{_SEP}") :]] = z[key]
        elif key.startswith(f"gidx{_SEP}"):
            gidx[key[len(f"gidx{_SEP}") :]] = z[key]
        elif key.startswith(f"order{_SEP}"):
            order[key[len(f"order{_SEP}") :]] = z[key]
    extras = {"step": meta.pop("step", 0), "global_index": gidx, "importance_order": order, "meta": meta}
    return _unflatten(flat_params), extras
