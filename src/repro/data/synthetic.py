"""Synthetic datasets + the paper's Non-IID partition.

No datasets ship offline, so the FL experiments run on synthetic
classification tasks with CIFAR-like cardinality: class-conditional image
distributions (random class prototypes + structured noise) that a CNN can
actually learn, so accuracy orderings between methods are meaningful.

Non-IID partition follows [36]/AdaptCL §IV-A exactly: (1-s%) of the data is
split IID across workers; the remaining s% is sorted by label and dealt
sequentially — every worker has the same amount of data but skewed classes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Tuple

import numpy as np

__all__ = [
    "SyntheticImageTask",
    "partition_noniid",
    "partition_dirichlet",
    "batch_iterator",
    "SyntheticLMTask",
]


@dataclasses.dataclass
class SyntheticImageTask:
    """Class-prototype images + noise; learnable but not trivial."""

    num_classes: int = 10
    image_size: int = 32
    train_size: int = 5000
    test_size: int = 1000
    noise: float = 0.6
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        s = self.image_size
        # low-frequency class prototypes
        low = rng.normal(0, 1, (self.num_classes, 8, 8, 3))
        protos = np.stack([
            np.kron(low[c], np.ones((s // 8, s // 8, 1))) for c in range(self.num_classes)
        ])
        self.prototypes = protos / np.abs(protos).max()

        def make(n, seed):
            r = np.random.default_rng(seed)
            y = r.integers(0, self.num_classes, n)
            x = self.prototypes[y] + r.normal(0, self.noise, (n, s, s, 3))
            return x.astype(np.float32), y.astype(np.int32)

        self.x_train, self.y_train = make(self.train_size, self.seed + 1)
        self.x_test, self.y_test = make(self.test_size, self.seed + 2)


def partition_noniid(
    y: np.ndarray, num_workers: int, s_percent: float, seed: int = 0
) -> List[np.ndarray]:
    """AdaptCL Non-IID split: returns per-worker index arrays (equal sizes)."""
    n = len(y)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_sorted = int(n * s_percent / 100.0)
    iid_part, skew_part = perm[: n - n_sorted], perm[n - n_sorted :]
    skew_part = skew_part[np.argsort(y[skew_part], kind="stable")]
    shards: List[List[int]] = [[] for _ in range(num_workers)]
    for w in range(num_workers):
        shards[w].extend(iid_part[w::num_workers])
    chunk = len(skew_part) // num_workers
    for w in range(num_workers):
        lo = w * chunk
        hi = (w + 1) * chunk if w < num_workers - 1 else len(skew_part)
        shards[w].extend(skew_part[lo:hi])
    return [np.array(sh, dtype=np.int64) for sh in shards]


def partition_dirichlet(
    y: np.ndarray, num_workers: int, alpha: float, seed: int = 0
) -> List[np.ndarray]:
    """Dirichlet label-concentration Non-IID split (``ScenarioConfig.skew``).

    Each worker draws a class mixture ``p_w ~ Dir(alpha)`` (small alpha =
    near single-class shards, large alpha = IID); floor quotas per class are
    filled from shuffled per-class pools, then the leftover indices are dealt
    shuffled so every shard has EXACTLY ``n // num_workers`` samples — the
    resident engines stack shard data into ``[W, n_shard, ...]`` arrays and
    require equal sizes.  Pure host numpy on one dedicated ``default_rng``
    stream, so the assignment is a function of ``(y, W, alpha, seed)`` alone
    and every engine sees identical shards."""
    n = len(y)
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    pools = {
        int(c): rng.permutation(np.flatnonzero(y == c)).tolist()
        for c in classes
    }
    n_shard = n // num_workers
    mix = rng.dirichlet(np.full(len(classes), alpha), size=num_workers)
    shards: List[List[int]] = [[] for _ in range(num_workers)]
    for w in range(num_workers):
        quota = np.floor(mix[w] * n_shard).astype(np.int64)
        for ci, c in enumerate(classes):
            pool = pools[int(c)]
            take = min(int(quota[ci]), len(pool), n_shard - len(shards[w]))
            if take > 0:
                shards[w].extend(pool[:take])
                del pool[:take]
    leftover = [i for c in classes for i in pools[int(c)]]
    rng.shuffle(leftover)
    pos = 0
    for w in range(num_workers):
        need = n_shard - len(shards[w])
        shards[w].extend(leftover[pos : pos + need])
        pos += need
    return [np.sort(np.array(sh, dtype=np.int64)) for sh in shards]


def batch_iterator(
    x: np.ndarray, y: np.ndarray, batch_size: int, epochs: float, rng: np.random.Generator
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """`epochs` may be fractional (DC-ASGD uses E=0.5)."""
    n = len(x)
    total = int(round(epochs * n))
    done = 0
    while done < total:
        order = rng.permutation(n)
        for i in range(0, n, batch_size):
            if done >= total:
                return
            idx = order[i : i + batch_size]
            yield x[idx], y[idx]
            done += len(idx)


@dataclasses.dataclass
class SyntheticLMTask:
    """Token sequences from a sparse Markov chain (for transformer smoke/train)."""

    vocab_size: int = 512
    seq_len: int = 64
    seed: int = 0

    def sample(self, batch: int, rng: np.random.Generator) -> np.ndarray:
        trans = np.random.default_rng(self.seed).integers(
            0, self.vocab_size, (self.vocab_size, 4)
        )
        toks = np.empty((batch, self.seq_len), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, batch)
        for t in range(1, self.seq_len):
            choice = rng.integers(0, 4, batch)
            toks[:, t] = trans[toks[:, t - 1], choice]
        return toks
