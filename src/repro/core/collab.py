"""Collaborative learning ON the mesh: workers as data-axis slices.

The FL simulator (`core.simulation`) reproduces the paper's host-level
protocol; this module maps the same semantics onto jax-native collectives for
the production mesh (DESIGN.md §2): every slice of the ``data`` axis is one
*worker* holding its private shard of the batch, sub-models are expressed as
nested CIG unit masks in base coordinates, and By-worker aggregation is a
single masked ``psum``:

    theta_g  =  (1/W) * psum_over_data( mask_w * theta_w )

Pruned coordinates contribute exact zeros — bitwise the paper's Alg. 1 line 5
semantics — and the aggregation collective appears in the lowered HLO like
any other production all-reduce (it is *the* communication the paper's
bandwidth model prices).

This file is deliberately model-agnostic: it works on flat {path: array}
params with a ``unit_map`` (same contract as core.aggregation), so the CNN
models and any future flat-parameter model can ride the same step.
"""
from __future__ import annotations

from typing import Callable, Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.sharding.compat import shard_map_compat

from .aggregation import UnitMap
from .masks import GlobalIndex

__all__ = ["make_worker_masks", "collab_round", "local_sgd_steps"]

Params = Dict[str, jnp.ndarray]


def make_worker_masks(
    indices: Sequence[GlobalIndex],
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> Params:
    """Stack per-worker coordinate masks: {path: [W, *shape] float32}."""
    from .aggregation import coordinate_mask

    out: Dict[str, np.ndarray] = {}
    for path, shape in base_shapes.items():
        ms = [coordinate_mask(path, idx, unit_map, base_shapes) for idx in indices]
        out[path] = np.stack(ms).astype(np.float32)
    return {k: jnp.asarray(v) for k, v in out.items()}


def local_sgd_steps(
    loss_fn: Callable[[Params, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    params: Params,
    x: jnp.ndarray,
    y: jnp.ndarray,
    *,
    lr: float,
    steps: int,
    batch_size: int,
) -> Params:
    """`steps` plain-SGD minibatch steps on this worker's shard (jit-inlined)."""

    n = x.shape[0]

    def body(p, i):
        lo = (i * batch_size) % jnp.maximum(n - batch_size + 1, 1)
        xb = jax.lax.dynamic_slice_in_dim(x, lo, batch_size, axis=0)
        yb = jax.lax.dynamic_slice_in_dim(y, lo, batch_size, axis=0)
        g = jax.grad(loss_fn)(p, xb, yb)
        return jax.tree.map(lambda w, gw: w - lr * gw, p, g), None

    params, _ = jax.lax.scan(body, params, jnp.arange(steps))
    return params


def collab_round(
    loss_fn: Callable,
    global_params: Params,
    masks: Params,           # [W, *shape] per path (make_worker_masks)
    x: jnp.ndarray,          # [W * n_local, ...] worker-sharded data
    y: jnp.ndarray,
    mesh,
    *,
    lr: float = 0.05,
    steps: int = 4,
    batch_size: int = 32,
    axis: str = "data",
) -> Params:
    """One synchronous AdaptCL round as a single SPMD program.

    Each ``data`` slice: extract its sub-model (mask), run local SGD on its
    shard, submit; the server aggregation is the closing masked psum / W.
    Returns the new global (base-coordinate) parameters, replicated.
    """
    W = mesh.shape[axis]

    def worker(gp, mask_w, xw, yw):
        # theta_w = theta_g ⊙ I_w  (masked extraction; reconfigured-shape
        # extraction is the simulator's job — here shapes stay static so the
        # whole round is one XLA program)
        mask_w = jax.tree.map(lambda m: m[0], mask_w)          # [1,*] -> [*]
        theta = jax.tree.map(lambda g, m: g * m, gp, mask_w)

        def masked_loss(p, xb, yb):
            return loss_fn(jax.tree.map(lambda w, m: w * m, p, mask_w), xb, yb)

        theta = local_sgd_steps(masked_loss, theta, xw, yw, lr=lr,
                                steps=steps, batch_size=batch_size)
        theta = jax.tree.map(lambda w, m: w * m, theta, mask_w)
        # By-worker aggregation: pruned coords are zeros; coefficient 1/W
        return jax.tree.map(lambda w: jax.lax.psum(w, axis) / W, theta)

    pspec_rep = jax.tree.map(lambda _: P(), global_params)
    pspec_masks = jax.tree.map(lambda _: P(axis), masks)
    return shard_map_compat(
        worker,
        mesh=mesh,
        in_specs=(pspec_rep, pspec_masks, P(axis), P(axis)),
        out_specs=pspec_rep,
    )(global_params, masks, x, y)
