"""Worker-side logic (AdaptCL Alg. 1, worker part).

SparseTrain -> NetworkPrune -> NetworkReconfigure.  A worker holds a
*reconfigured* (physically small) sub-model plus its global index I_w.
Training steps are jitted per parameter-shape signature; a reconfiguration
triggers one recompilation (counted in the overhead benchmark — this is the
JAX analogue of PruneTrain's model rebuild).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn import CNNConfig, cnn_apply
from repro.optim.group_lasso import group_lasso_penalty
from repro.optim.optimizers import apply_updates, momentum

from .masks import GlobalIndex, prune_to_budget

__all__ = ["LocalTrainer", "reslice_subparams", "local_unit_stats"]

Params = Dict[str, np.ndarray]


def reslice_subparams(
    params: Params, old_index: GlobalIndex, new_index: GlobalIndex, unit_map
) -> Params:
    """Slice a sub-model further down: new_index must nest inside old_index."""
    rel: Dict[str, np.ndarray] = {}
    for lname, old in old_index.items():
        pos = {int(u): i for i, u in enumerate(old)}
        rel[lname] = np.array([pos[int(u)] for u in new_index[lname]], dtype=np.int64)
    out: Params = {}
    for path, arr in params.items():
        for lname, axis in unit_map.get(path, ()):
            arr = np.take(arr, rel[lname], axis=axis)
        out[path] = arr
    return out


class LocalTrainer:
    """Minibatch SGD(+momentum) with optional group-lasso sparse training."""

    def __init__(self, cnn_cfg: CNNConfig, lr: float = 0.05, beta: float = 0.9):
        self.cfg = cnn_cfg
        self.lr = lr
        self.beta = beta
        self._step_cache: Dict = {}
        self.compile_count = 0  # reconfigure-induced recompiles (overhead bench)

    def _get_step(self, params: Params, unit_map, lam: float):
        sig = (tuple(sorted((k, v.shape) for k, v in params.items())), lam > 0.0)
        if sig in self._step_cache:
            return self._step_cache[sig]
        cfg, lr, beta = self.cfg, self.lr, self.beta
        opt = momentum(lr, beta)
        frozen_map = {k: tuple(v) for k, v in unit_map.items()}

        def loss_fn(p, x, y):
            logits = cnn_apply(p, cfg, x)
            logp = jax.nn.log_softmax(logits)
            ce = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
            if lam > 0.0:
                ce = ce + group_lasso_penalty(p, frozen_map, lam)
            return ce

        @jax.jit
        def step(p, opt_state, x, y):
            loss, grads = jax.value_and_grad(loss_fn)(p, x, y)
            updates, opt_state = opt.update(grads, opt_state, p)
            return apply_updates(p, updates), opt_state, loss

        @jax.jit
        def grad_fn(p, x, y):
            return jax.grad(loss_fn)(p, x, y)

        entry = (step, opt.init, grad_fn)
        self._step_cache[sig] = entry
        self.compile_count += 1
        return entry

    def train(
        self,
        params: Params,
        unit_map,
        x: np.ndarray,
        y: np.ndarray,
        epochs: float,
        batch_size: int,
        rng: np.random.Generator,
        lam: float = 0.0,
    ) -> Tuple[Params, float]:
        """Returns (new params, mean loss)."""
        if epochs <= 0:
            return params, float("nan")
        step, opt_init, _ = self._get_step(params, unit_map, lam)
        p = {k: jnp.asarray(v) for k, v in params.items()}
        opt_state = opt_init(p)
        losses = []
        n = len(x)
        total = max(1, int(round(epochs * n)))
        done = 0
        while done < total:
            order = rng.permutation(n)
            for i in range(0, n, batch_size):
                if done >= total:
                    break
                sel = order[i : i + batch_size]
                if len(sel) < batch_size:  # keep shapes static for the jit cache
                    sel = np.concatenate([sel, order[: batch_size - len(sel)]])
                p, opt_state, loss = step(p, opt_state, jnp.asarray(x[sel]), jnp.asarray(y[sel]))
                losses.append(float(loss))
                done += batch_size
        return {k: np.asarray(v) for k, v in p.items()}, float(np.mean(losses))

    def gradient(self, params: Params, unit_map, x, y, lam: float = 0.0) -> Params:
        """One-batch gradient (DC-ASGD commits gradients, not models)."""
        _, _, grad_fn = self._get_step(params, unit_map, lam)
        g = grad_fn({k: jnp.asarray(v) for k, v in params.items()}, jnp.asarray(x), jnp.asarray(y))
        return {k: np.asarray(v) for k, v in g.items()}

    # ---- Alg. 1 lines 3-5: prune + reconfigure ---------------------------

    def prune_and_reconfigure(
        self,
        params: Params,
        index: GlobalIndex,
        scores: Mapping[str, np.ndarray],
        pruned_rate: float,
        space,
        unit_map,
    ) -> Tuple[Params, GlobalIndex]:
        new_index = prune_to_budget(index, scores, pruned_rate, space)
        new_params = reslice_subparams(params, index, new_index, unit_map)
        return new_params, new_index


def local_unit_stats(
    trainer: LocalTrainer,
    params: Params,
    index: GlobalIndex,
    space,
    unit_map,
    x: np.ndarray,
    y: np.ndarray,
) -> Dict[str, Dict[str, np.ndarray]]:
    """Data/sub-model-dependent importance signals, scattered to base unit
    coordinates (missing units get -inf so they sort as already-pruned).

    weight_norms -> L1/FPGM; grads -> Taylor |g.w|; activations -> HRank proxy.
    """
    from repro.optim.group_lasso import unit_group_norms

    jparams = {k: jnp.asarray(v) for k, v in params.items()}
    norms, _ = unit_group_norms(jparams, unit_map)
    grads = trainer.gradient(params, unit_map, x[:64], y[:64])
    gw = {}
    for lname in norms:
        acc = 0.0
        for path, entries in unit_map.items():
            for ln, axis in entries:
                if ln != lname:
                    continue
                g = np.asarray(grads[path], np.float64)
                w = np.asarray(params[path], np.float64)
                axes = tuple(i for i in range(g.ndim) if i != axis)
                acc = acc + np.abs((g * w).sum(axis=axes))
        gw[lname] = acc
    # activation statistic (HRank proxy): real per-filter mean|activation|
    stats: Dict[str, jnp.ndarray] = {}
    cnn_apply(jparams, trainer.cfg, jnp.asarray(x[:64]), stats=stats)
    acts = {
        lname: np.asarray(stats[lname], np.float64) for lname in norms if lname in stats
    }

    def scatter(local: np.ndarray, lname: str) -> np.ndarray:
        full = np.full(space.layer(lname).num_units, -np.inf)
        full[np.asarray(index[lname], np.int64)] = np.asarray(local, np.float64)
        return full

    return {
        "weight_norms": {k: scatter(np.asarray(v), k) for k, v in norms.items()},
        "grads": {k: scatter(v, k) for k, v in gw.items()},
        "activations": {k: scatter(v, k) for k, v in acts.items()},
    }
