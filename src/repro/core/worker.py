"""Worker-side logic (AdaptCL Alg. 1, worker part).

SparseTrain -> NetworkPrune -> NetworkReconfigure.  A worker holds a
*reconfigured* (physically small) sub-model plus its global index I_w.
Training steps are jitted per parameter-shape signature; a reconfiguration
triggers one recompilation (counted in the overhead benchmark — this is the
JAX analogue of PruneTrain's model rebuild).

Three training entry points:

* ``train`` / ``train_plan`` — one worker per call (the sequential engine);
* ``train_many`` — a *stack* of same-shaped workers trained in one jitted
  ``vmap``-of-``scan`` call (stacked params, stacked shards, stacked batch
  plans, stacked optimizer state), optionally with per-worker 0/1 parameter
  masks so heterogeneous sub-models can share the base shape (the fleet
  engine's bucketed/masked modes, see ``core.fleet``);
* ``train_resident`` — the resident fleet path: device-resident ``[W, ...]``
  base-shape stacks in, stacks out, with a per-step validity mask so ragged
  batch plans (and per-round participation) never change device shapes — an
  invalidated step leaves the carry untouched, so a worker with ``k`` valid
  steps trains exactly like a ``k``-step plan and a fully-invalid worker
  passes through unchanged.

Batch order is decoupled from the training loop via ``make_batch_plan`` so
every engine consumes the *same* minibatch sequence from the same RNG —
that is what makes the engines numerically equivalent.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.cnn import CNNConfig, cnn_apply, prunable_layer_names
from repro.optim.group_lasso import group_lasso_penalty, group_size_sqrt
from repro.optim.optimizers import apply_updates, momentum

from .masks import GlobalIndex, prune_to_budget

__all__ = [
    "LocalTrainer",
    "make_batch_plan",
    "plan_steps",
    "stack_batch_plans",
    "reslice_subparams",
    "local_unit_stats",
]

Params = Dict[str, np.ndarray]


def make_batch_plan(
    n: int, batch_size: int, epochs: float, rng: np.random.Generator
) -> np.ndarray:
    """Pre-draw the minibatch index sequence for one local training phase.

    Returns ``[steps, batch_size]`` int64 indices into the worker's shard,
    replicating ``LocalTrainer.train``'s batching exactly (fresh permutation
    per epoch, short final batch padded from the epoch's head, fractional
    epochs honoured).  ``epochs <= 0`` returns an empty ``[0, batch_size]``
    plan without consuming RNG state.
    """
    if epochs <= 0 or n <= 0:
        return np.zeros((0, batch_size), dtype=np.int64)
    total = max(1, int(round(epochs * n)))
    sels = []
    done = 0
    while done < total:
        order = rng.permutation(n)
        for i in range(0, n, batch_size):
            if done >= total:
                break
            sel = order[i : i + batch_size]
            if len(sel) < batch_size:  # keep shapes static for the jit cache
                sel = np.concatenate([sel, order[: batch_size - len(sel)]])
            sels.append(sel.astype(np.int64))
            done += batch_size
    return np.stack(sels)


def plan_steps(n: int, batch_size: int, epochs: float) -> int:
    """Number of steps ``make_batch_plan(n, batch_size, epochs, ...)`` draws,
    without consuming RNG state.

    The fleet engine uses this to pick a *constant* step pad for a whole run
    phase (the max over every worker slot), so gathered sub-stacks keep one
    plan shape no matter which subset participates — the step dimension never
    forces a recompile."""
    if epochs <= 0 or n <= 0:
        return 0
    total = max(1, int(round(epochs * n)))
    return -(-total // batch_size)


def stack_batch_plans(
    plans: Sequence[Optional[np.ndarray]],
    num_rows: Optional[int] = None,
    num_steps: Optional[int] = None,
):
    """Pad per-row batch plans into ``[R, S, batch]`` + a ``[R, S]`` validity
    mask (``None``/empty plan = fully invalid row).

    ``num_rows``/``num_steps`` pad the row and step dimensions beyond the
    given plans (padding rows/steps are invalid, so the resident trainer
    compute-and-discards them) — this is how gathered sub-stacks are bucketed
    to a small set of device shapes.  Returns ``None`` when no row has a real
    step and no explicit padding was requested."""
    steps = [0 if p is None else p.shape[0] for p in plans]
    S = max(steps) if steps else 0
    if num_steps is not None:
        S = max(S, num_steps)
    if S == 0:
        return None
    R = len(plans)
    if num_rows is not None:
        R = max(R, num_rows)
    batch = next(
        (p.shape[1] for p in plans if p is not None and p.shape[0] > 0), 1
    )
    stack = np.zeros((R, S, batch), np.int64)
    valid = np.zeros((R, S), np.float32)
    for w, p in enumerate(plans):
        if steps[w]:
            stack[w, : steps[w]] = p
            valid[w, : steps[w]] = 1.0
    return stack, valid


def reslice_subparams(
    params: Params, old_index: GlobalIndex, new_index: GlobalIndex, unit_map
) -> Params:
    """Slice a sub-model further down: new_index must nest inside old_index."""
    rel: Dict[str, np.ndarray] = {}
    for lname, old in old_index.items():
        pos = {int(u): i for i, u in enumerate(old)}
        rel[lname] = np.array([pos[int(u)] for u in new_index[lname]], dtype=np.int64)
    out: Params = {}
    for path, arr in params.items():
        for lname, axis in unit_map.get(path, ()):
            arr = np.take(arr, rel[lname], axis=axis)
        out[path] = arr
    return out


class LocalTrainer:
    """Minibatch SGD(+momentum) with optional group-lasso sparse training.

    ``compute`` selects the masked paths' device dispatch: ``"dense"`` runs
    base-shape ``lax.conv`` programs (masks as 0/1 multiplies — full FLOPs),
    ``"block_skip"`` lowers the convs + head onto the ``kernels.pruned_matmul``
    block-skip kernel with per-worker unit masks (derived from each worker's
    ``bn_g`` mask rows), so a pruned worker's device FLOPs track its
    retention.  Only the masked/resident paths honour it — the unmasked
    engines run physically reconfigured models, which are already sized.
    ``interpret=None`` auto-selects per backend (Python interpreter off-TPU).
    """

    def __init__(
        self,
        cnn_cfg: CNNConfig,
        lr: float = 0.05,
        beta: float = 0.9,
        compute: str = "dense",
        compute_blocks: Tuple[int, int, int] = (128, 128, 128),
        interpret: Optional[bool] = None,
    ):
        if compute not in ("dense", "block_skip"):
            raise ValueError(f"unknown compute path {compute!r}")
        self.cfg = cnn_cfg
        self.lr = lr
        self.beta = beta
        self.compute = compute
        self.compute_blocks = tuple(compute_blocks)
        if interpret is None:
            from repro.kernels.ops import auto_interpret

            interpret = auto_interpret()
        self.compute_interpret = bool(interpret)
        self._prunable = prunable_layer_names(cnn_cfg)
        self._step_cache: Dict = {}
        self.compile_count = 0  # reconfigure-induced recompiles (overhead bench)
        self.dispatch_count = 0  # jitted training programs launched (host->device)
        self.compile_walltime_s = 0.0  # wall spent in FIRST calls (compile + 1 run)

    # ---- jit-cache plumbing ----------------------------------------------

    def _call_cached(self, sig, build, *args, count_compile: bool = True):
        """Dispatch a jitted program through the signature cache.

        Every call counts toward ``dispatch_count`` (the per-round host
        dispatch metric ``SimResult.host_dispatches`` reports); the FIRST
        call of each signature is timed to completion (``block_until_ready``)
        and accumulated into ``compile_walltime_s``, so benchmarks can
        separate warm-up (trace + compile + one run) from steady-state
        walltime.  ``count_compile=False`` keeps a signature out of
        ``compile_count`` (``SimResult.recompiles`` means *training-program*
        recompiles — evaluation helpers are timed but not counted there)."""
        entry = self._step_cache.get(sig)
        if entry is None:
            entry = [build(), False]
            self._step_cache[sig] = entry
            if count_compile:
                self.compile_count += 1
        self.dispatch_count += 1
        fn, warm = entry
        if warm:
            return fn(*args)
        t0 = _time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        self.compile_walltime_s += _time.perf_counter() - t0
        entry[1] = True
        return out

    def _masked_logits(self, qm, mask, xb):
        """Logits of the masked base-shape model; the block-skip path reads
        each prunable layer's unit mask off its ``bn_g`` mask row (the
        [width] 0/1 vector the fleet's ``refresh_masks`` writes)."""
        if self.compute == "block_skip":
            um = {n: mask[f"{n}/bn_g"] for n in self._prunable}
            return cnn_apply(
                qm, self.cfg, xb, compute="block_skip", unit_masks=um,
                blocks=self.compute_blocks, interpret=self.compute_interpret,
            )
        return cnn_apply(qm, self.cfg, xb)

    def _masked_ce(self, qm, mask, xb, yb):
        """Mean cross-entropy of the masked model (shared by the masked
        stacked and resident train closures)."""
        logp = jax.nn.log_softmax(self._masked_logits(qm, mask, xb))
        return -jnp.take_along_axis(logp, yb[:, None], axis=1).mean()

    def _make_loss(self, unit_map, lam: float):
        cfg = self.cfg
        frozen_map = {k: tuple(v) for k, v in unit_map.items()}

        def loss_fn(p, x, y):
            logits = cnn_apply(p, cfg, x)
            logp = jax.nn.log_softmax(logits)
            ce = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
            if lam > 0.0:
                ce = ce + group_lasso_penalty(p, frozen_map, lam)
            return ce

        return loss_fn

    def _grad_call(self, params: Params, unit_map, lam: float, *args):
        sig = self._plan_sig(params, "grad", lam)
        return self._call_cached(
            sig, lambda: jax.jit(jax.grad(self._make_loss(unit_map, lam))), *args
        )

    def train(
        self,
        params: Params,
        unit_map,
        x: np.ndarray,
        y: np.ndarray,
        epochs: float,
        batch_size: int,
        rng: np.random.Generator,
        lam: float = 0.0,
    ) -> Tuple[Params, float]:
        """Returns (new params, mean loss) — make_batch_plan + train_plan."""
        plan = make_batch_plan(len(x), batch_size, epochs, rng)
        return self.train_plan(params, unit_map, x, y, plan, lam)

    # ---- plan-based training (fleet engine paths) ------------------------

    def _make_plan_train(self, unit_map, lam: float, masked: bool):
        """scan-over-plan trainer for ONE worker; vmap-able across a stack.

        The masked variant takes the worker's 0/1 parameter mask plus its
        sqrt-group-size factors (``group_size_sqrt`` of the *reconfigured*
        sub-model) so the group-lasso penalty matches the physically small
        model exactly, not the base shapes the masked program runs at.
        """
        cfg, opt = self.cfg, momentum(self.lr, self.beta)
        frozen_map = {k: tuple(v) for k, v in unit_map.items()}

        def ce(p, xb, yb):
            logits = cnn_apply(p, cfg, xb)
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, yb[:, None], axis=1).mean()

        def scan_train(loss_fn, p, x, y, plan):
            opt_state = opt.init(p)

            def body(carry, sel):
                q, st = carry
                loss, grads = jax.value_and_grad(loss_fn)(q, x[sel], y[sel])
                updates, st = opt.update(grads, st, q)
                return (apply_updates(q, updates), st), loss

            (p, _), losses = jax.lax.scan(body, (p, opt_state), plan)
            return p, jnp.mean(losses)

        if not masked:

            def train_one(p, x, y, plan):
                def loss_fn(q, xb, yb):
                    l = ce(q, xb, yb)
                    if lam > 0.0:
                        l = l + group_lasso_penalty(q, frozen_map, lam)
                    return l

                return scan_train(loss_fn, p, x, y, plan)

        else:

            def train_one(p, x, y, plan, mask, gl_size):
                def loss_fn(q, xb, yb):
                    qm = jax.tree.map(lambda w, m: w * m, q, mask)
                    l = self._masked_ce(qm, mask, xb, yb)
                    if lam > 0.0:
                        l = l + group_lasso_penalty(qm, frozen_map, lam, size_sqrt=gl_size)
                    return l

                p, loss = scan_train(loss_fn, p, x, y, plan)
                return jax.tree.map(lambda w, m: w * m, p, mask), loss

        return train_one

    def _plan_sig(self, params: Params, extra, lam: float) -> tuple:
        # lam is baked into the compiled closure, so it must key the cache
        return (tuple(sorted((k, v.shape) for k, v in params.items())), extra, float(lam))

    def train_plan(
        self, params: Params, unit_map, x: np.ndarray, y: np.ndarray,
        plan: np.ndarray, lam: float = 0.0,
    ) -> Tuple[Params, float]:
        """Train one worker through a pre-drawn ``make_batch_plan`` plan."""
        if plan.shape[0] == 0:
            return {k: np.asarray(v) for k, v in params.items()}, float("nan")
        sig = self._plan_sig(params, ("plan", x.shape, plan.shape), lam)
        p, loss = self._call_cached(
            sig,
            lambda: jax.jit(self._make_plan_train(unit_map, lam, masked=False)),
            {k: jnp.asarray(v) for k, v in params.items()},
            jnp.asarray(x), jnp.asarray(y), jnp.asarray(plan),
        )
        return {k: np.asarray(v) for k, v in p.items()}, float(loss)

    def train_many(
        self,
        params_list: Sequence[Params],
        unit_map,
        xs: np.ndarray,           # [B, n, ...] stacked shards
        ys: np.ndarray,           # [B, n]
        plans: np.ndarray,        # [B, steps, batch]
        lam: float = 0.0,
        masks: Optional[Sequence[Params]] = None,   # per-worker 0/1, same shapes
        gl_sizes: Optional[Sequence[Dict[str, float]]] = None,  # sqrt|g| per layer
    ) -> Tuple[List[Params], List[float]]:
        """Train a stack of same-shaped workers in ONE jitted vmapped call.

        All workers must share a parameter-shape signature (the fleet engine
        buckets by it); ``masks`` turns on the masked mode where heterogeneous
        sub-models ride the base shape as 0/1 unit masks, so gradients (and
        the stacked momentum state) are exactly zero on pruned coordinates.
        """
        B = len(params_list)
        assert xs.shape[0] == ys.shape[0] == plans.shape[0] == B
        stacked = {
            k: jnp.stack([jnp.asarray(p[k]) for p in params_list])
            for k in params_list[0]
        }
        masked = masks is not None
        sig = self._plan_sig(
            params_list[0], ("many", B, xs.shape[1:], plans.shape[1:], masked), lam
        )
        args = [stacked, jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(plans)]
        if masked:
            args.append({
                k: jnp.stack([jnp.asarray(m[k]) for m in masks])
                for k in params_list[0]
            })
            if gl_sizes is None:  # fall back to the shapes the stack runs at
                gl_sizes = [group_size_sqrt(p, unit_map) for p in params_list]
            args.append({
                lname: jnp.asarray([s[lname] for s in gl_sizes], jnp.float32)
                for lname in gl_sizes[0]
            })
        out, losses = self._call_cached(
            sig,
            lambda: jax.jit(jax.vmap(self._make_plan_train(unit_map, lam, masked=masked))),
            *args,
        )
        return (
            [{k: np.asarray(v[i]) for k, v in out.items()} for i in range(B)],
            [float(l) for l in losses],
        )

    # ---- resident fleet path (core.fleet.FleetState) ---------------------

    def make_resident_train(self, unit_map, lam: float, carry_momentum: bool = False):
        """One base-shape masked worker with step-validity gating; vmapped
        across the whole resident ``[W, ...]`` stack by ``train_resident``
        (and embedded, un-jitted, inside the fused round engine's scan).

        Valid steps replicate the masked ``_make_plan_train`` step exactly;
        an invalid step computes-and-discards (params, momentum and loss all
        keep their carry), which is how ragged plans and non-participating
        workers share one compiled program.

        ``carry_momentum`` switches the optimizer state from the per-phase
        reset of the reference engines to a caller-supplied carry: the
        returned ``train_one`` then takes the incoming momentum stack as an
        extra leading state argument (the cross-round resident-momentum
        mode), instead of ``opt.init``-ing zeros every phase.
        """
        cfg, opt = self.cfg, momentum(self.lr, self.beta)
        frozen_map = {k: tuple(v) for k, v in unit_map.items()}

        def train_one(p, x, y, plan, valid, mask, gl_size, m0=None):
            def loss_fn(q, xb, yb):
                qm = jax.tree.map(lambda w, m: w * m, q, mask)
                l = self._masked_ce(qm, mask, xb, yb)
                if lam > 0.0:
                    l = l + group_lasso_penalty(qm, frozen_map, lam, size_sqrt=gl_size)
                return l

            opt_state = m0 if carry_momentum else opt.init(p)

            def body(carry, step):
                sel, v = step
                vb = v > 0
                q, st = carry
                loss, grads = jax.value_and_grad(loss_fn)(q, x[sel], y[sel])
                updates, st2 = opt.update(grads, st, q)
                q2 = apply_updates(q, updates)
                q = jax.tree.map(lambda a, b: jnp.where(vb, a, b), q2, q)
                st = jax.tree.map(lambda a, b: jnp.where(vb, a, b), st2, st)
                return (q, st), jnp.where(vb, loss, 0.0)

            (p, opt_state), losses = jax.lax.scan(body, (p, opt_state), (plan, valid))
            p = jax.tree.map(lambda w, m: w * m, p, mask)
            steps = jnp.maximum(valid.sum(), 1.0)
            return p, opt_state, losses.sum() / steps

        return train_one

    def train_resident(
        self,
        params_stack: Dict[str, jnp.ndarray],   # [W, ...] base-shape stacks
        masks_stack: Dict[str, jnp.ndarray],    # [W, ...] 0/1
        unit_map,
        xs: jnp.ndarray,                        # [W, n_max, ...] padded shards
        ys: jnp.ndarray,                        # [W, n_max]
        plans: jnp.ndarray,                     # [W, steps, batch]
        valid: jnp.ndarray,                     # [W, steps] 1.0 = real step
        lam: float = 0.0,
        gl_sizes: Optional[Dict[str, jnp.ndarray]] = None,   # {lname: [W]}
        momentum_in: Optional[Dict[str, jnp.ndarray]] = None,  # [W, ...] carry
    ):
        """One jitted program over the ENTIRE resident fleet stack.

        Returns (params_stack, momentum_stack, losses[W]) — all stacks stay
        jnp arrays, so nothing round-trips through the host.  When
        ``momentum_in`` is given, the optimizer state starts from that stack
        instead of zeros (cross-round resident momentum; the returned
        momentum stack is the carry for the next phase/round).
        """
        carry_m = momentum_in is not None
        shapes_sig = tuple(sorted((k, tuple(v.shape)) for k, v in params_stack.items()))
        sig = (shapes_sig, ("resident", xs.shape, plans.shape, carry_m), float(lam))

        def build():
            one = self.make_resident_train(unit_map, lam, carry_momentum=carry_m)
            if carry_m:
                def with_m(p, m0, x, y, plan, valid, mask, gl_size):
                    return one(p, x, y, plan, valid, mask, gl_size, m0)
                return jax.jit(jax.vmap(with_m))
            return jax.jit(jax.vmap(one))

        if gl_sizes is None:   # base-shape factors for every worker
            W = plans.shape[0]
            gl_sizes = {
                lname: jnp.full((W,), s, jnp.float32)
                for lname, s in group_size_sqrt(
                    {k: v[0] for k, v in params_stack.items()}, unit_map
                ).items()
            }
        if carry_m:
            return self._call_cached(
                sig, build, params_stack, momentum_in, xs, ys, plans, valid,
                masks_stack, gl_sizes,
            )
        return self._call_cached(
            sig, build, params_stack, xs, ys, plans, valid, masks_stack, gl_sizes
        )

    def gradient(self, params: Params, unit_map, x, y, lam: float = 0.0) -> Params:
        """One-batch gradient (DC-ASGD commits gradients, not models)."""
        g = self._grad_call(
            params, unit_map, lam,
            {k: jnp.asarray(v) for k, v in params.items()},
            jnp.asarray(x), jnp.asarray(y),
        )
        return {k: np.asarray(v) for k, v in g.items()}

    # ---- Alg. 1 lines 3-5: prune + reconfigure ---------------------------

    def prune_and_reconfigure(
        self,
        params: Params,
        index: GlobalIndex,
        scores: Mapping[str, np.ndarray],
        pruned_rate: float,
        space,
        unit_map,
    ) -> Tuple[Params, GlobalIndex]:
        new_index = prune_to_budget(index, scores, pruned_rate, space)
        new_params = reslice_subparams(params, index, new_index, unit_map)
        return new_params, new_index


def local_unit_stats(
    trainer: LocalTrainer,
    params: Params,
    index: GlobalIndex,
    space,
    unit_map,
    x: np.ndarray,
    y: np.ndarray,
) -> Dict[str, Dict[str, np.ndarray]]:
    """Data/sub-model-dependent importance signals, scattered to base unit
    coordinates (missing units get -inf so they sort as already-pruned).

    weight_norms -> L1/FPGM; grads -> Taylor |g.w|; activations -> HRank proxy.
    """
    from repro.optim.group_lasso import unit_group_norms

    jparams = {k: jnp.asarray(v) for k, v in params.items()}
    norms, _ = unit_group_norms(jparams, unit_map)
    grads = trainer.gradient(params, unit_map, x[:64], y[:64])
    gw = {}
    for lname in norms:
        acc = 0.0
        for path, entries in unit_map.items():
            for ln, axis in entries:
                if ln != lname:
                    continue
                g = np.asarray(grads[path], np.float64)
                w = np.asarray(params[path], np.float64)
                axes = tuple(i for i in range(g.ndim) if i != axis)
                acc = acc + np.abs((g * w).sum(axis=axes))
        gw[lname] = acc
    # activation statistic (HRank proxy): real per-filter mean|activation|
    stats: Dict[str, jnp.ndarray] = {}
    cnn_apply(jparams, trainer.cfg, jnp.asarray(x[:64]), stats=stats)
    acts = {
        lname: np.asarray(stats[lname], np.float64) for lname in norms if lname in stats
    }

    def scatter(local: np.ndarray, lname: str) -> np.ndarray:
        full = np.full(space.layer(lname).num_units, -np.inf)
        full[np.asarray(index[lname], np.int64)] = np.asarray(local, np.float64)
        return full

    return {
        "weight_norms": {k: scatter(np.asarray(v), k) for k, v in norms.items()},
        "grads": {k: scatter(v, k) for k, v in gw.items()},
        "activations": {k: scatter(v, k) for k, v in acts.items()},
    }
