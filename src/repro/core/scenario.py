"""Scenario layer: client sampling, dropout, churn (FedPrune/FedMP regimes).

The interesting collaborative-learning regimes are hundreds of *partially
participating, flaky* clients — FedAvg-style client sampling (fraction ``C``
per round), stragglers that miss the round deadline (dropout with
straggler-timeout semantics), and device churn (a worker leaves and is
replaced by a fresh one with a fresh data shard).

All three are expressed as a per-round :class:`RoundEvents` record — boolean
masks over a FIXED worker slot space — so the resident fleet engine
(``core.fleet.FleetState``) applies them as participation masks over its
``[W, ...]`` stacks: device shapes never change, and the masked engine keeps
its one-compile guarantee no matter how flaky the fleet is.

Semantics (documented here, implemented by ``core.simulation._run_sync``):

* **sampling** — ``max(min_participants, round(participation * W))`` workers
  drawn uniformly without replacement train and (attempt to) submit each
  round; everyone else idles and keeps their sub-model identity.
* **dropout** — each sampled worker independently fails to report with
  probability ``dropout`` (at least one submitter always survives).  The
  server applies a straggler timeout: if anyone dropped, the round costs
  ``timeout_factor`` x the slowest *received* update.  Dropped updates are
  discarded (the worker re-fetches the global model like everyone else).
* **churn** — each worker slot is replaced with probability ``churn`` at
  round start: full (unpruned) sub-model, fresh data shard, fresh
  pruned-rate history / DGC residuals.  Replacement keeps ``W`` constant —
  the fleet is a slot pool, as in semi-async FL systems.

Scenarios apply in full to the synchronous methods (``fedavg``,
``fedavg_s``, ``adaptcl``).  The async schedulers model client *pacing*
through their event queue already, but they honour **client sampling**:
``participation`` selects a static ``max(min_participants, round(C * W))``
subset of the slot pool (``static_participants``, drawn from the same
dedicated RNG stream) that joins the event loop — the resident engine then
sizes its device compute to the participants, not the slot pool.  They also
honour **dropout**, with natural async semantics: each event-queue commit
independently times out at the server with probability ``dropout`` (drawn
from the scenario RNG stream in heap pop order, one draw per event, only
when ``dropout > 0``).  A timed-out commit still trains (the worker did the
work), still counts toward the worker's round quota and SSP's progress
counters, and still refetches the current global — but its update is
discarded: no merge, no version bump, no communicated bytes.  Churn and
per-round schedules stay sync-only (slot replacement and scripted rounds
reset host bookkeeping the event queue does not model) and are rejected for
async methods.

The whole async run is pre-simulated on host into an :class:`AsyncEventPlan`
(``simulation._plan_async_events``) — the async analogue of
:class:`ScenarioPlan`: commit order (including finish-time ties), staleness
integers, dropout outcomes, refetch sets and virtual clocks are fixed before
any training runs, so the per-worker, resident and fused engines consume ONE
event stream by construction.

``ScenarioConfig.schedule`` takes explicit per-round events for tests and
reproducible sweeps; rounds beyond the schedule fall back to full
participation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .faults import FaultConfig

__all__ = [
    "AsyncEventPlan",
    "FaultConfig",
    "ScenarioConfig",
    "RoundEvents",
    "ScenarioEngine",
    "ScenarioPlan",
    "full_participation",
    "shard_cohorts",
]


def shard_cohorts(
    rows: Sequence[int], num_workers: int, num_shards: int
) -> List[np.ndarray]:
    """Split a sampled cohort's GLOBAL slot ids into per-shard LOCAL row sets
    under the mesh-sharded fleet's contiguous layout (shard ``s`` owns slots
    ``[s*W_local, (s+1)*W_local)``).  This is the shard-aware form of cohort
    sampling: per-shard gathers take ``out[s]`` — local indices that cannot
    fall outside the shard — instead of raw global ids (which a per-shard
    ``take`` would silently clamp).  Ids within each shard keep their draw
    order."""
    from .fleet import global_to_shard_local   # lazy: keep scenario light

    shard_ids, local = global_to_shard_local(rows, num_workers, num_shards)
    return [
        np.asarray(local[shard_ids == s], np.int64)
        for s in range(num_shards)
    ]


@dataclasses.dataclass
class RoundEvents:
    """One round's participation outcome over the fixed worker slots.

    The fault fields default to ``None``/``False`` and stay that way on a
    fault-free run, so pre-feature three-field constructions (tests,
    scripted schedules) and the fault-free fast path are untouched."""

    active: np.ndarray    # bool [W]: sampled to train this round
    dropped: np.ndarray   # bool [W]: subset of active that never reports
    joined: np.ndarray    # bool [W]: slot churned at round start (fresh worker)
    # --- fault overlay (core.faults), None/False when faults are off ---
    offline: Optional[np.ndarray] = None    # bool [W]: crashed / region dark
    recovered: Optional[np.ndarray] = None  # bool [W]: back online this round
    recovering: Optional[np.ndarray] = None  # bool [W]: re-joining, no aggreg.
    drift_mult: Optional[np.ndarray] = None  # f64 [W]: update-time multiplier
    skip: bool = False          # round skipped: submitters < min_participants
    degraded: bool = False      # aggregated a fault-reduced partial cohort
    drift_changed: bool = False  # drift multiplier changed at this round
    # --- adversarial / lossy-channel overlay, None when those families off ---
    byz: Optional[np.ndarray] = None        # bool [W]: compromised this round
    delivered: Optional[np.ndarray] = None  # bool [W]: commit survived channel
    dup: Optional[np.ndarray] = None        # bool [W]: delivered twice
    corrupt: Optional[np.ndarray] = None    # bool [W]: payload garbled
    retries: Optional[np.ndarray] = None    # int64 [W]: failed uplink attempts

    @property
    def submitters(self) -> np.ndarray:
        sub = self.active & ~self.dropped
        if self.recovering is not None:
            sub = sub & ~self.recovering
        return sub


def full_participation(num_workers: int) -> RoundEvents:
    on = np.ones(num_workers, dtype=bool)
    off = np.zeros(num_workers, dtype=bool)
    return RoundEvents(active=on, dropped=off.copy(), joined=off.copy())


@dataclasses.dataclass
class ScenarioConfig:
    participation: float = 1.0      # C: fraction of workers sampled per round
    dropout: float = 0.0            # P(sampled worker misses the deadline)
    churn: float = 0.0              # P(slot replaced at round start)
    min_participants: int = 1
    timeout_factor: float = 1.5     # straggler deadline multiplier on drop
    seed: int = 0
    # explicit per-round events (tests / reproducible sweeps); overrides draws
    schedule: Optional[Sequence[RoundEvents]] = None
    # scripted fault world (core.faults): capability drift, crash/recovery,
    # regional outages, participation waves, Byzantine workers, lossy
    # channels.  None => pre-feature behavior, bit for bit (zero extra RNG
    # draws on any stream).
    faults: Optional[FaultConfig] = None
    # Non-IID shard skew: Dirichlet label-concentration parameter for the
    # initial shard assignment (lower = more skewed; None = the default
    # sorted-split partitioner).  Applied once before any engine runs, so it
    # is engine-identical by construction; churned-in shards stay uniform.
    skew: Optional[float] = None


class ScenarioEngine:
    """Draws per-round :class:`RoundEvents` from a dedicated RNG stream.

    The stream is independent of the simulator's data/jitter RNG, so the same
    scenario unfolds identically under every fleet engine — which is what the
    cross-engine scenario-equivalence tests pin down."""

    def __init__(self, cfg: ScenarioConfig, num_workers: int):
        if not (0.0 < cfg.participation <= 1.0):
            raise ValueError(f"participation {cfg.participation} outside (0, 1]")
        if not (0.0 <= cfg.dropout < 1.0):
            raise ValueError(f"dropout {cfg.dropout} outside [0, 1)")
        if not (0.0 <= cfg.churn < 1.0):
            raise ValueError(f"churn {cfg.churn} outside [0, 1)")
        if cfg.min_participants < 1:
            raise ValueError(f"min_participants {cfg.min_participants} must be >= 1")
        if cfg.timeout_factor < 1.0:
            raise ValueError(
                f"timeout_factor {cfg.timeout_factor} must be >= 1.0: the "
                "straggler deadline is a multiplier on the slowest received "
                "update, and a factor below 1 would end the round before "
                "its own submitters finish"
            )
        if cfg.skew is not None and not (cfg.skew > 0.0):
            raise ValueError(f"scenario skew {cfg.skew} must be > 0")
        if cfg.faults is not None:
            if cfg.faults.drift is not None and cfg.faults.drift.worker >= num_workers:
                raise ValueError(
                    f"drift worker {cfg.faults.drift.worker} outside the "
                    f"{num_workers}-slot pool"
                )
            if cfg.faults.outage is not None and cfg.faults.outage.slot_hi > num_workers:
                raise ValueError(
                    f"outage slots [{cfg.faults.outage.slot_lo}, "
                    f"{cfg.faults.outage.slot_hi}) outside the "
                    f"{num_workers}-slot pool"
                )
            byz = cfg.faults.byzantine
            if byz is not None and byz.workers is not None and max(byz.workers) >= num_workers:
                raise ValueError(
                    f"byzantine workers {byz.workers} outside the "
                    f"{num_workers}-slot pool"
                )
        self.cfg = cfg
        self.W = num_workers
        self.rng = np.random.default_rng(cfg.seed + 9173)
        # Dedicated fault stream: crash draws come from here (one [W] vector
        # per round, round order), NEVER from self.rng — so enabling faults
        # does not perturb the sampling/dropout/churn stream, and a
        # fault-free run consumes zero draws from either stream for faults.
        self.fault_rng = np.random.default_rng(cfg.seed + 40961)
        self._faults_on = cfg.faults is not None and cfg.faults.any_active
        # crash/outage state machine: worker w is offline while
        # round < _offline_until[w], then re-joining (trains, refetches, not
        # aggregated) while round < _recover_until[w].
        self._offline_until = np.zeros(num_workers, dtype=np.int64)
        self._recover_until = np.zeros(num_workers, dtype=np.int64)
        self._prev_offline = np.zeros(num_workers, dtype=bool)

    def draw(self, round_t: int) -> RoundEvents:
        """Events for 1-based round ``round_t``."""
        cfg, W = self.cfg, self.W
        if cfg.schedule is not None:
            if round_t - 1 < len(cfg.schedule):
                ev = cfg.schedule[round_t - 1]
                ev = RoundEvents(
                    active=np.asarray(ev.active, bool).copy(),
                    dropped=np.asarray(ev.dropped, bool).copy(),
                    joined=np.asarray(ev.joined, bool).copy(),
                )
                if not ev.active.any():
                    raise ValueError(
                        f"schedule round {round_t} samples no workers"
                    )
                if not ev.submitters.any():
                    # same invariant as the random path: the timeout never
                    # starves the round of all submitters
                    ev.dropped[np.flatnonzero(ev.active)[0]] = False
            else:
                ev = full_participation(W)
        else:
            joined = self.rng.random(W) < cfg.churn
            k = self.cohort_size(round_t)
            active = np.zeros(W, dtype=bool)
            active[self.rng.choice(W, size=k, replace=False)] = True
            dropped = active & (self.rng.random(W) < cfg.dropout)
            if dropped.all() or not (active & ~dropped).any():
                # straggler timeout never starves the round: keep one submitter
                dropped[np.flatnonzero(active)[0]] = False
            ev = RoundEvents(active=active, dropped=dropped, joined=joined)
        if self._faults_on:
            ev = self._apply_faults(round_t, ev)
        return ev

    def _apply_faults(self, round_t: int, ev: RoundEvents) -> RoundEvents:
        """Overlay the scripted fault world onto one round's base draw.

        Runs AFTER the base draw so the sampling/dropout/churn stream is
        byte-identical with or without faults; the only stochastic family
        (crash) draws one [W] vector per round from the dedicated
        ``fault_rng``.  The fault state machine advances here — ``draw``
        must be called once per round in order (both the lazy loop and
        ``draw_all`` do)."""
        faults = self.cfg.faults
        base_active = ev.active.copy()
        outage_now = np.zeros(self.W, dtype=bool)
        if faults.outage is not None and faults.outage.covers(round_t):
            outage_now[faults.outage.slot_lo:faults.outage.slot_hi] = True
        if faults.crash is not None:
            crash_now = self.fault_rng.random(self.W) < faults.crash.rate
            # only currently-online workers can crash (a dark region or an
            # already-crashed worker has nothing left to lose this round)
            crash_now &= (round_t >= self._offline_until) & ~outage_now
            hit = np.flatnonzero(crash_now)
            self._offline_until[hit] = round_t + faults.crash.outage_rounds
            self._recover_until[hit] = (
                self._offline_until[hit] + faults.crash.recovery_rounds
            )
        offline = (round_t < self._offline_until) | outage_now
        recovered = ~offline & self._prev_offline
        recovering = ~offline & (round_t < self._recover_until)
        self._prev_offline = offline
        ev.offline = offline
        ev.recovered = recovered
        ev.recovering = recovering
        ev.active = ev.active & ~offline
        ev.dropped = ev.dropped & ev.active
        ev.joined = ev.joined & ~offline
        if faults.drift is not None:
            ev.drift_mult = self.drift_mults(round_t)
            ev.drift_changed = self.drift_changed(round_t)
        if faults.byzantine is not None:
            # fixed compromised set: deterministic, zero RNG; fractional set:
            # one [W] block per round, drawn unconditionally so the stream
            # never depends on who was sampled this round
            if faults.byzantine.workers is not None:
                byz = np.zeros(self.W, dtype=bool)
                byz[list(faults.byzantine.workers)] = True
            else:
                byz = self.fault_rng.random(self.W) < faults.byzantine.fraction
            ev.byz = byz
        if faults.channel is not None:
            ch = faults.channel
            # fixed draw block per round: attempts, then dup, then corrupt
            fails = self.fault_rng.random((self.W, ch.max_retries + 1)) < ch.drop
            dup_u = self.fault_rng.random(self.W)
            corrupt_u = self.fault_rng.random(self.W)
            delivered = ~fails.all(axis=1)
            # retries = failed attempts consumed: attempts before the first
            # success, or the whole retry budget when every attempt failed
            first_ok = np.argmax(~fails, axis=1)
            ev.retries = np.where(delivered, first_ok, ch.max_retries).astype(np.int64)
            ev.delivered = delivered
            ev.dup = delivered & (dup_u < ch.dup)
            ev.corrupt = delivered & (corrupt_u < ch.corrupt)
        n_sub = int(ev.submitters.sum())
        if n_sub < self.cfg.min_participants:
            # graceful degradation floor: too few survivors to aggregate —
            # skip the round (virtual clock still advances, global untouched)
            ev.skip = True
        else:
            ev.degraded = bool(
                (base_active & offline).any() or (ev.active & recovering).any()
                or (ev.delivered is not None
                    and (ev.submitters & ~ev.delivered).any())
            )
        return ev

    def drift_mults(self, round_t: int) -> np.ndarray:
        """Per-worker update-time multipliers in force at ``round_t``.

        Pure in ``round_t`` (no state, no RNG) so the fused engine's
        chunk-boundary scan can probe future rounds without perturbing the
        stream."""
        mults = np.ones(self.W, dtype=np.float64)
        drift = self.cfg.faults.drift if self.cfg.faults is not None else None
        if drift is not None:
            mults[drift.worker] = drift.mult_at(round_t)
        return mults

    def drift_changed(self, round_t: int) -> bool:
        """True when the drift multiplier changes AT ``round_t`` — the
        trigger for prune-rate re-learning (re-enter Alg. 2)."""
        drift = self.cfg.faults.drift if self.cfg.faults is not None else None
        if drift is None or round_t < 1:
            return False
        return drift.mult_at(round_t) != drift.mult_at(round_t - 1)

    def cohort_size(self, round_t: Optional[int] = None) -> int:
        """Sampled cohort size: ``clip(round(C * W), min_participants, W)`` —
        the ONE formula behind both the sync per-round draw and the async
        static cohort, so the two can't diverge.  With a diurnal wave fault
        and a round index, C becomes the time-varying C(t)."""
        cfg = self.cfg
        part = cfg.participation
        if (
            round_t is not None
            and cfg.faults is not None
            and cfg.faults.wave is not None
        ):
            part = min(part * cfg.faults.wave.factor_at(round_t), 1.0)
        return int(np.clip(round(part * self.W),
                           cfg.min_participants, self.W))

    def static_participants(self) -> np.ndarray:
        """Slot ids participating in an ASYNC run, drawn once at run start.

        Async client sampling: a ``cohort_size()`` subset joins the event
        loop; the rest of the slot pool idles for the whole run.  Sorted
        ascending so the initial schedule order matches the
        full-participation loop, and drawn from the scenario RNG stream so
        the same subset participates under every engine."""
        k = self.cohort_size()
        return np.sort(self.rng.choice(self.W, size=k, replace=False)).astype(np.int64)

    def fresh_shard(self, size: int, train_len: int) -> np.ndarray:
        """Index set for a churned-in worker (uniform over the task's pool)."""
        return self.rng.choice(train_len, size=size, replace=False).astype(np.int64)

    def draw_all(
        self,
        rounds: int,
        shard_sizes: Optional[Sequence[int]] = None,
        train_len: int = 0,
    ) -> "ScenarioPlan":
        """Pre-draw the ENTIRE run's events (the fused engine's path).

        Consumes the scenario RNG stream in exactly the per-round order of
        the lazy sync loop — ``draw(t)`` then one ``fresh_shard`` per joined
        slot in ascending slot order — so a pre-drawn plan unfolds
        *identically* to round-by-round draws under every engine.  Fresh
        shards for churned slots are drawn here too (they interleave with
        the event draws on the shared stream); ``shard_sizes``/``train_len``
        are only needed when churn is enabled."""
        events: List[RoundEvents] = []
        fresh: List[Dict[int, np.ndarray]] = []
        for t in range(1, rounds + 1):
            ev = self.draw(t)
            shards: Dict[int, np.ndarray] = {}
            for w in np.flatnonzero(ev.joined):
                if shard_sizes is None:
                    raise ValueError("draw_all needs shard_sizes when churn > 0")
                shards[int(w)] = self.fresh_shard(int(shard_sizes[w]), train_len)
            events.append(ev)
            fresh.append(shards)
        return ScenarioPlan(events=events, fresh_shards=fresh)


@dataclasses.dataclass
class ScenarioPlan:
    """A whole run's pre-drawn scenario: per-round events + churn shards.

    ``as_arrays`` stacks the boolean masks into ``[R, W]`` matrices — the
    form the fused engine uploads to device (submitter weights, activity
    masks) so the scan consumes one row per fused round."""

    events: List[RoundEvents]
    fresh_shards: List[Dict[int, np.ndarray]]

    def as_arrays(self) -> Dict[str, np.ndarray]:
        return {
            "active": np.stack([e.active for e in self.events]),
            "dropped": np.stack([e.dropped for e in self.events]),
            "joined": np.stack([e.joined for e in self.events]),
            "submitters": np.stack([e.submitters for e in self.events]),
        }

    @staticmethod
    def full(rounds: int, num_workers: int) -> "ScenarioPlan":
        return ScenarioPlan(
            events=[full_participation(num_workers) for _ in range(rounds)],
            fresh_shards=[{} for _ in range(rounds)],
        )


@dataclasses.dataclass
class AsyncEventPlan:
    """A whole async run's pre-simulated discrete-event stream.

    Built by ``simulation._plan_async_events`` from an exact host replay of
    the heap loop (finish-time heap with ``(time, worker)`` tie-breaking,
    identical ``env.rng`` jitter/plan draw order, identical SSP blocking
    walk), with training removed — possible because async workers never
    prune, so event timing is independent of trained parameter values.  All
    arrays are indexed by event ``i`` in HEAP POP ORDER (= commit order);
    ``batch_starts`` delimits the window batches the engines execute.

    ``push_seq`` records the order events were *pushed* into the pending
    queue: the fused engine feeds each batch's events to the device in push
    order and lets the device sorted-queue pop (``fused.async_pop_perm``,
    a ``lexsort`` over split-float64 finish keys then worker index) recover
    the commit order — which a per-chunk runtime check compares back against
    ``workers``/``staleness``, so a divergent device pop raises instead of
    silently reordering commits."""

    workers: np.ndarray        # int64 [E]: committing worker, heap pop order
    finishes: np.ndarray       # f64 [E]: event finish time (heap key)
    push_seq: np.ndarray       # int64 [E]: global push counter at schedule()
    staleness: np.ndarray      # int64 [E]: server.version - fetched_ver[w]
    versions: np.ndarray       # int64 [E]: server version AFTER the event
    dropped: np.ndarray        # bool [E]: commit timed out (no merge)
    refetch: np.ndarray        # bool [E, W]: rows refetching the new global
    evals: np.ndarray          # bool [E]: accuracy eval after this commit
    clocks: np.ndarray         # f64 [E]: running-max virtual clock
    batch_starts: np.ndarray   # int64 [B+1]: window-batch event offsets
    plans: List[np.ndarray]    # per-event batch plans, env.rng draw order
    # crash-fault accounting baked at plan time (None when faults are off);
    # both async engines surface it verbatim, so ledgers cannot diverge
    fault_ledger: Optional[Dict[str, int]] = None

    @property
    def num_events(self) -> int:
        return len(self.workers)
