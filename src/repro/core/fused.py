"""Fused round engine: whole synchronous rounds as on-device ``lax.scan`` chunks.

The resident masked engine (``core.fleet.FleetState``) already keeps the
fleet's ``[W, ...]`` stacks on device, but every round still pays a host
boundary: a jit dispatch for each train phase, a ``params_host`` pull, NumPy
float64 aggregation, host importance scoring and ``prune_to_budget``, and a
``refresh_masks`` rewrite — per round, per fleet.  This module removes that
boundary: ``SimConfig.engine = "fused"`` expresses the ENTIRE synchronous
round — masked broadcast-back (``theta_g[None] * M``), vmapped fleet
training, stacked aggregation, importance scoring, budget pruning, and
mask-row refresh — in pure ``jnp`` over the resident stacks, and runs chunks
of rounds as a single ``jax.lax.scan`` device program.  R rounds execute in
``O(R / round_fusion)`` host dispatches (``SimResult.host_dispatches``)
instead of ``O(R)``.

**Chunk boundaries.**  Newton pruned-rate learning (``core.pruned_rate``,
scalar host math over per-worker histories) stays on host — it is the
natural fusion boundary: chunks span the rounds BETWEEN prune-rate-learning
events (every ``prune_interval`` rounds for ``adaptcl``), capped at
``SimConfig.round_fusion`` when set.  Churn rounds also cut chunks (a slot
replacement swaps data shards and resets host bookkeeping); sampling and
dropout are pure participation masks and fuse freely.

**Engine-identical decisions.**  Everything the host path draws from RNG is
pre-drawn in the SAME stream order: scenario events come from
``ScenarioEngine.draw_all`` (events + churn shards, the dedicated scenario
stream), batch plans and channel-jitter multipliers are drawn per round in
the lazy loop's exact ``env.rng`` order during chunk pre-compute.  Pruning
replays host ``prune_to_budget`` exactly: removal ORDERS for the
data-independent criteria are host-exact integer permutations
(``masks.prune_order``, float64 scores + ``(score, layer, unit)``
tie-break), budgets are exact integer thresholds (``prune_budget_units``),
and the device greedy (``prune_presence_rows``) replays the same walk — so
given the same scores, the removed unit sets are bit-identical.  The
seed-derived criteria (``index``/``no_adjacent``/``no_identical``/
``no_constant``) therefore carry an UNCONDITIONAL bit-identity guarantee;
``cig_bnscalor``'s frozen scores are |BN gamma| of the trained global at
the freeze event, which differs across engines at float32-drift scale
(fused aggregates in f32 on device, the host paths in f64), so a near-tie
inside that drift could in principle reorder two units — the equivalence
tests pin index equality on real runs.  Data-dependent criteria
(``l1``/``taylor``, ``importance.DEVICE_METHODS``) are scored on device in
float32 with the same caveat.

The host recovers per-round ``GlobalIndex`` values lazily from the scan's
``[K, W, U]`` presence outputs — ONLY for payload/FLOPs accounting and the
channel model, after the chunk has already run.  Per-round aggregated
globals come back as stacked scan outputs, so ``eval_every`` never forces a
chunk split.

**Async fusion.**  The asynchronous schedulers (``fedasync_s`` / ``ssp_s``
/ ``dcasgd_s``) fuse too (``run_async_fused``): the whole discrete-event
run is pre-simulated on host into a ``scenario.AsyncEventPlan``
(``simulation._plan_async_events`` — possible because async workers never
prune, so event timing is independent of trained parameter values), and
chunks of ``round_fusion`` window batches then run as ONE ``lax.scan``
program each.  Inside the scan the pending-commit queue is a device array:
each batch's events arrive in heap PUSH order with split-float64 finish
keys, ``async_pop_perm`` (a ``lexsort`` — sorted finish-times replacing the
host heap) re-derives the commit order including the host heap's
``(time, worker)`` tie-break, and an inner scan walks the commits through
``aggregation.async_commit_jnp`` merges, integer staleness counters
(``version - fetched_ver``), dropout gating, and masked refetch
(``fleet.refetch_rows_jnp``).  A per-chunk runtime check compares the
device pop order and staleness integers against the plan and raises on
divergence, so commit schedules are bit-identical to the resident engine
by construction — E events run in ``O(E / round_fusion)`` host dispatches.

**DGC on device.**  ``dgc_sparsity > 0`` runs INSIDE the scan:
``aggregation.dgc_compress_jnp`` top-|.|-compresses the ``[W, ...]`` delta
stacks (delta = trained params minus the masked broadcast-back) with the
residual accumulators carried in the scan state, and aggregation consumes
``theta_g[None] * M + committed``.  Keep sets are bit-identical to the host
compressor (``simulation._dgc_compress_stacked``): both compute keep
budgets with the same float32 rounding and threshold the same float32
values, mirroring how ``prune_order`` makes pruning host-exact.  Realized
per-round kept/total counts come back as ``[K, W]`` scan outputs, so the
payload factors feeding the channel model are the host path's exact
integers.

**Mask regrowth.**  FedDST-style readjustment (``SimConfig.regrow``) also
cuts chunks: a regrow round always opens a chunk, the shared host step
(``simulation._regrow_step``) rewrites the global indices at that boundary
(shrink by global weight magnitude, grow back by gradient magnitude — one
extra cached jit signature for the gradient), and the next chunk simply
starts from the readjusted presence rows.  The chunk program is unchanged,
so regrow costs zero recompiles.

Out of scope (see ROADMAP): participation-sized sub-stack gathering inside
a scan (fused rounds compute all W rows with validity masks), and the
``block_skip`` compute path under the scan (interpret-mode Pallas inside
``lax.scan`` is untested off-TPU).
"""
from __future__ import annotations

import time as _time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.cnn import cnn_flops_from_shapes, extract_bn_scales
from repro.sharding.compat import shard_map_compat
from repro.sharding.specs import fleet_sharding

from repro.optim.group_lasso import group_size_sqrt_from_shapes

from .aggregation import (
    aggregate_by_unit_stacked_jnp,
    aggregate_by_worker_stacked_jnp,
    async_commit_jnp,
    async_health_step_jnp,
    delta_norms_jnp,
    dgc_compress_jnp,
    extract_subparams,
    noise_key,
    robust_submission_step_jnp,
    roundtrip_total,
    subparam_shapes,
)
from .faults import fault_ledger
from .fleet import gl_factors_from_counts, masks_from_presence, refetch_rows_jnp
from .importance import (
    DEVICE_METHODS,
    METHODS,
    STATIC_METHODS,
    ImportanceContext,
    l1_scores_jnp,
    taylor_scores_jnp,
)
from .masks import (
    UnitFlat,
    flatten_unit_space,
    full_index,
    index_from_presence,
    presence_from_index,
    prune_budget_units,
    prune_order,
    prune_presence_rows,
    retention,
    similarity,
)
from .pruned_rate import WorkerHistory, learn_pruned_rates
from .scenario import ScenarioEngine, ScenarioPlan
from .timing import heterogeneity_from_times
from .worker import make_batch_plan, plan_steps, stack_batch_plans

__all__ = [
    "run_sync_fused",
    "run_async_fused",
    "async_pop_perm",
    "split_time_keys",
    "validate_fused_config",
]


def validate_fused_config(sim) -> None:
    """Reject configurations the fused engine does not express on device."""
    if sim.compute != "dense":
        raise ValueError(
            "engine='fused' supports compute='dense' only — the block_skip "
            "interpret-mode kernel inside lax.scan is out of scope off-TPU"
        )
    supported = STATIC_METHODS | DEVICE_METHODS
    if sim.importance not in supported:
        raise ValueError(
            f"engine='fused' supports importance criteria {sorted(supported)}; "
            f"{sim.importance!r} needs host-side statistics (use "
            "engine='masked')"
        )
    mesh = getattr(sim, "mesh", None)
    if mesh is not None:
        axis = sim.fleet_axis
        if axis not in mesh.shape:
            raise ValueError(
                f"SimConfig.mesh axes {tuple(mesh.shape)} have no fleet "
                f"axis {axis!r} (SimConfig.fleet_axis)"
            )
        n_dev = mesh.shape[axis]
        if sim.num_workers % n_dev:
            raise ValueError(
                f"num_workers={sim.num_workers} does not divide over the "
                f"{n_dev}-way {axis!r} mesh axis (W = n_dev x W_local)"
            )


def _static_orders(sim, env, flat: UnitFlat, cig_scores, prune_round_count):
    """Host-exact ``[W, U]`` removal orders for the data-independent
    criteria (``None`` while CIG scores are not yet frozen — unused then,
    because no prune can fire before the first learning event)."""
    W = sim.num_workers
    name = sim.importance
    if name == "cig_bnscalor":
        if cig_scores is None:
            return None
        return np.tile(prune_order(cig_scores, flat), (W, 1))
    ctx = dict(unit_counts=env.space.unit_counts, round=prune_round_count,
               seed=sim.seed)
    if name != "no_identical":    # one shared order across workers
        scores = METHODS[name](ImportanceContext(**ctx))
        return np.tile(prune_order(scores, flat), (W, 1))
    rows = []
    for w in range(W):
        scores = METHODS[name](ImportanceContext(worker=w, **ctx))
        rows.append(prune_order(scores, flat))
    return np.stack(rows)


def _build_chunk_fn(trainer, unit_map, base_shapes, flat: UnitFlat, lam,
                    *, by_unit: bool, importance: str,
                    resident_momentum: bool, has_phase_b: bool,
                    dgc_sparsity: float = 0.0,
                    mesh=None, fleet_axis: str = "fleet",
                    robust=None, byz=None, corrupt_std=None,
                    channel: bool = False, noise_seed: int = 0,
                    fleet_w=None):
    """Build the jitted chunk program: ``lax.scan`` over K fused rounds.

    Carry: (param stacks, mask stacks, flat presence, global params,
    momentum stacks) — everything a round needs, so nothing touches the host
    between scan steps.  Per-round inputs arrive as ``[K, ...]`` tensors;
    per-round outputs (post-prune presence, post-aggregation global) come
    back stacked so the host can account payloads/clock and evaluate lazily.

    **Mesh-sharded fleet** (``mesh`` set): the SAME chunk body runs under
    ``shard_map`` over the ``fleet_axis`` mesh axis — each device scans its
    ``W_local = W / n_dev`` rows.  Everything in a round is row-local
    (masked broadcast-back of the replicated global, vmapped training,
    presence pruning, device importance scores), EXCEPT aggregation, which
    becomes the two-tier on-mesh collective
    (``aggregate_by_*_stacked_jnp(axis=...)``: per-shard partial reduce,
    then a global ``psum``), after which the new global is replicated on
    every shard again.  One jit dispatch still covers the whole chunk, so
    host dispatches stay O(R / round_fusion) while W scales with devices.

    Prune-order bit-identity under sharding: removal orders for the static
    criteria ship from host as ``[W, U]`` integer rows (importance scores
    gathered/computed on HOST at prune events — never trained params), and
    the device-scored criteria (l1/taylor) reduce within a row only — no
    cross-worker collective touches a score, so sharding the row axis
    cannot reorder a removal walk."""
    train_one = trainer.make_resident_train(unit_map, lam, carry_momentum=True)
    vm_train = jax.vmap(
        lambda p, m0, x, y, plan, valid, mask, gl:
            train_one(p, x, y, plan, valid, mask, gl, m0)
    )
    slices = {
        name: (int(flat.offsets[l]), int(flat.sizes[l]))
        for l, name in enumerate(flat.names)
    }
    tiebreak_dev = jnp.asarray(flat.tiebreak)

    def counts_of(presence):
        return {
            name: presence[:, off : off + sz].sum(axis=1)
            for name, (off, sz) in slices.items()
        }

    def device_scores(params, masks, presence, xs, ys, sizes):
        """Data-dependent importance in base coordinates over the stacks.

        Mirrors ``worker.local_unit_stats`` + the host METHODS: masked unit
        group norms (l1) / per-unit |sum g.w| on the first <=64 shard images
        (taylor), non-retained slots scattered to -inf."""
        if importance == "l1":
            sq: Dict[str, jnp.ndarray] = {}
            for path, entries in unit_map.items():
                arr = params.get(path)
                if arr is None:
                    continue
                for lname, axis in entries:
                    axes = tuple(
                        i for i in range(arr.ndim) if i not in (0, 1 + axis)
                    )
                    s = jnp.sum(jnp.square(arr.astype(jnp.float32)), axis=axes)
                    sq[lname] = sq.get(lname, 0.0) + s
            norms = {k: jnp.sqrt(jnp.maximum(v, 1e-12)) for k, v in sq.items()}
            return l1_scores_jnp(norms, flat.names, presence)
        # taylor: grads of the masked CE on each worker's first <=64 images
        nb = min(64, xs.shape[1])
        xb, yb = xs[:, :nb], ys[:, :nb]
        wv = (
            jnp.arange(nb)[None, :] < jnp.minimum(sizes, nb)[:, None]
        ).astype(jnp.float32)

        def ce_one(q, mask, x, y, v):
            qm = jax.tree.map(lambda w, m: w * m, q, mask)
            logp = jax.nn.log_softmax(trainer._masked_logits(qm, mask, x))
            pick = jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]
            return -(pick * v).sum() / jnp.maximum(v.sum(), 1.0)

        grads = jax.vmap(
            lambda q, mask, x, y, v: jax.grad(ce_one)(q, mask, x, y, v)
        )(params, masks, xb, yb, wv)
        gw: Dict[str, jnp.ndarray] = {}
        for path, entries in unit_map.items():
            if path not in grads:
                continue
            for lname, axis in entries:
                g, w_ = grads[path], params[path]
                axes = tuple(i for i in range(g.ndim) if i not in (0, 1 + axis))
                gw[lname] = gw.get(lname, 0.0) + jnp.abs(
                    jnp.sum(g * w_, axis=axes)
                )
        return taylor_scores_jnp(gw, flat.names, presence)

    use_dgc = dgc_sparsity > 0.0
    # robust submission path: byzantine transform + channel corruption +
    # clip/trim/quarantine, all in-scan via robust_submission_step_jnp — the
    # SAME function the masked loop calls per round.  Static config; the
    # quarantine health state rides the carry (full-fleet [W] rows,
    # replicated under the mesh — health is a fleet-wide order statistic).
    # NOTE: a lossy channel with corrupt=0 still routes through the robust
    # path — the commit multiplicity (drop/dup) reshapes the weights and the
    # all-lost-round wsum==0 guard must be the SAME code as the masked loop.
    robust_on = (byz is not None or corrupt_std is not None
                 or robust is not None or channel)
    quar_cfg = robust.quarantine if robust is not None else None

    def chunk(params, momentum, presence, global_p, dgc_res, health, xs, ys,
              sizes, per_round, orders):
        masks = masks_from_presence(presence, flat, unit_map, base_shapes)

        def body(carry, inp):
            params, masks, presence, global_p, momentum, dgc_res, health = carry
            # crash recovery at the round start, in-scan: rows flagged in
            # inp["recov"] re-enter with their last mask but restart
            # velocity/DGC residuals (they were accumulated against
            # pre-crash parameters).  All-zero on fault-free rounds, so the
            # compiled program is shared and the fault-free math unchanged.
            if resident_momentum or use_dgc:
                keep = 1.0 - inp["recov"]
                if resident_momentum:
                    momentum = {
                        k: v * keep.reshape((-1,) + (1,) * (v.ndim - 1))
                        for k, v in momentum.items()
                    }
                if use_dgc:
                    dgc_res = {
                        k: v * keep.reshape((-1,) + (1,) * (v.ndim - 1))
                        for k, v in dgc_res.items()
                    }
            # broadcast-back: masked scatter of the global into every row
            params = {k: global_p[k][None] * masks[k] for k in params}
            gl = gl_factors_from_counts(
                counts_of(presence), unit_map, base_shapes
            )
            m0 = (momentum if resident_momentum
                  else jax.tree.map(jnp.zeros_like, params))
            params, m_out, _ = vm_train(
                params, m0, xs, ys, inp["plan_a"], inp["valid_a"], masks, gl
            )
            momentum = m_out if resident_momentum else momentum

            def prune_branch(op):
                params, masks, presence, momentum = op
                if importance in STATIC_METHODS:
                    ow = orders
                else:
                    scores = device_scores(
                        params, masks, presence, xs, ys, sizes
                    )
                    ow = jax.vmap(
                        lambda s: jnp.lexsort((tiebreak_dev, s)).astype(jnp.int32)
                    )(scores)
                pres2 = prune_presence_rows(presence, ow, inp["budgets"], flat)
                masks2 = masks_from_presence(pres2, flat, unit_map, base_shapes)
                params2 = {k: params[k] * masks2[k] for k in params}
                mom2 = (
                    {k: momentum[k] * masks2[k] for k in momentum}
                    if resident_momentum else momentum
                )
                if has_phase_b:
                    gl2 = gl_factors_from_counts(
                        counts_of(pres2), unit_map, base_shapes
                    )
                    m0b = (mom2 if resident_momentum
                           else jax.tree.map(jnp.zeros_like, params2))
                    params2, m_b, _ = vm_train(
                        params2, m0b, xs, ys,
                        inp["plan_b"], inp["valid_b"], masks2, gl2,
                    )
                    mom2 = m_b if resident_momentum else mom2
                return params2, masks2, pres2, mom2

            params, masks, presence, momentum = jax.lax.cond(
                inp["prune_any"], prune_branch, lambda op: op,
                (params, masks, presence, momentum),
            )

            # submission boundary: DGC top-|.| delta compression on device.
            # Deltas are vs the masked broadcast-back; submitters-gated, so
            # dead padding rounds (submitters all 0) touch no residual.
            if use_dgc:
                deltas = {
                    k: params[k] - global_p[k][None] * masks[k] for k in params
                }
                committed, dgc_res, kept_w, total_w = dgc_compress_jnp(
                    deltas, dgc_res, dgc_sparsity, masks, inp["submitters"]
                )
                agg_in = {
                    k: global_p[k][None] * masks[k] + committed[k]
                    for k in params
                }
            else:
                agg_in = params
                kept_w = total_w = None

            agg_axis = fleet_axis if mesh is not None else None
            quar_row = None
            if by_unit:
                g_new = aggregate_by_unit_stacked_jnp(
                    agg_in, masks, inp["submitters"], axis=agg_axis
                )
            elif robust_on:
                # noise keys derive from the ROUND NUMBER in-scan via the
                # same fold_in chain the masked loop runs eagerly — threefry
                # is deterministic, so the streams are bit-identical.
                byz_key = (
                    noise_key(noise_seed + 51721, inp["rnd"])
                    if byz is not None else None
                )
                cor_key = (
                    noise_key(noise_seed + 51722, inp["rnd"])
                    if corrupt_std is not None else None
                )
                g_new, st2, qu2, quar_row = robust_submission_step_jnp(
                    agg_in, masks, global_p, inp["mult"], inp["weights"],
                    inp["byz"] if byz is not None else None,
                    inp["corrupt"] if corrupt_std is not None else None,
                    byz_key, cor_key,
                    health.get("strikes"), health.get("quar"),
                    byz_mode=byz.mode if byz is not None else "sign_flip",
                    byz_scale=byz.scale if byz is not None else -10.0,
                    byz_noise_std=byz.noise_std if byz is not None else 1.0,
                    corrupt_std=corrupt_std if corrupt_std is not None else 10.0,
                    clip=robust.clip if robust is not None else None,
                    trim=robust.trim if robust is not None else 0.0,
                    quarantine=quar_cfg,
                    gate=inp["real"], axis=agg_axis, full_rows=fleet_w,
                )
                if quar_cfg is not None:
                    health = {"strikes": st2, "quar": qu2}
            else:
                g_new = aggregate_by_worker_stacked_jnp(
                    agg_in, inp["weights"], axis=agg_axis
                )
            # dead padding rounds (real=False) keep the global untouched, so
            # every chunk shares ONE [K]-shaped compiled program
            global_p = {
                k: jnp.where(inp["real"], g_new[k].astype(jnp.float32),
                             global_p[k])
                for k in global_p
            }
            return (
                params, masks, presence, global_p, momentum, dgc_res, health
            ), (presence, global_p, kept_w, total_w, quar_row)

        carry0 = (params, masks, presence, global_p, momentum, dgc_res, health)
        (params, masks, presence, global_p, momentum, dgc_res, health), (
            pres_seq, glob_seq, kept_seq, total_seq, quar_seq
        ) = jax.lax.scan(body, carry0, per_round)
        return (params, momentum, presence, global_p, dgc_res, health,
                pres_seq, glob_seq, kept_seq, total_seq, quar_seq)

    if mesh is None:
        return jax.jit(chunk)

    # one lax.scan program PER SHARD: row-stacked args shard over the fleet
    # axis (dim 0 for state, dim 1 for [K, W, ...] per-round tensors), the
    # global and the per-round scalars replicate; outputs mirror that, with
    # the post-psum global (and its [K, ...] eval trail) replicated.
    fleet, rep = P(fleet_axis), P()
    per_round_specs = {
        "plan_a": P(None, fleet_axis), "valid_a": P(None, fleet_axis),
        "budgets": P(None, fleet_axis), "prune_any": rep, "real": rep,
        "weights": P(None, fleet_axis), "submitters": P(None, fleet_axis),
        "recov": P(None, fleet_axis),
    }
    if has_phase_b:
        per_round_specs["plan_b"] = P(None, fleet_axis)
        per_round_specs["valid_b"] = P(None, fleet_axis)
    # robust per-round rows shard like the other [K, W] tensors; the round
    # numbers (noise-key seeds) are scalars every shard needs — full-W noise
    # is generated per shard then row-sliced for bit-identity — so replicate.
    if robust_on:
        per_round_specs["mult"] = P(None, fleet_axis)
        per_round_specs["rnd"] = rep
    if byz is not None:
        per_round_specs["byz"] = P(None, fleet_axis)
    if corrupt_std is not None:
        per_round_specs["corrupt"] = P(None, fleet_axis)
    # kept/total [K, W] scan outputs shard like the presence trail; the DGC
    # residual stacks join the fleet-sharded state (all row-local math).
    # When DGC is off those slots are empty pytrees and the specs are inert.
    # Quarantine health state (and the quar trail) is a fleet-wide order
    # statistic computed on gathered norms — replicated [W] rows.
    kt = P(None, fleet_axis)
    return jax.jit(shard_map_compat(
        chunk, mesh=mesh,
        in_specs=(fleet, fleet, fleet, rep, fleet, rep, fleet, fleet, fleet,
                  per_round_specs, fleet),
        out_specs=(fleet, fleet, fleet, rep, fleet, rep, P(None, fleet_axis),
                   rep, kt, kt, rep),
    ))


def run_sync_fused(sim, env):
    """Synchronous simulation with the fused round engine (see module doc).

    Mirrors ``simulation._run_sync`` decision-for-decision; the differences
    are WHERE things run (rounds on device in scan chunks, accounting on
    host after each chunk), never WHAT is computed.
    """
    from .simulation import (   # lazy: no import cycle
        _env_accuracy,
        _finalize,
        _regrow_round,
        _regrow_step,
        _skip_round_time,
    )

    validate_fused_config(sim)
    W = sim.num_workers
    use_dgc = sim.dgc_sparsity > 0.0
    adapt = sim.method == "adaptcl"
    sparse = sim.method in ("fedavg_s", "adaptcl")
    lam = sim.lam if sparse else 0.0
    trainer = env.trainer
    unit_map = env.unit_map
    base_shapes = env.base_shapes
    flat = flatten_unit_space(env.space)
    U = flat.num_units
    mesh = getattr(sim, "mesh", None)
    state_sharding = (
        fleet_sharding(mesh, sim.fleet_axis) if mesh is not None else None
    )

    # robust-aggregation statics (byzantine transform / lossy channel /
    # clip-trim-quarantine).  All None => the chunk program and every host
    # array below are byte-for-byte the pre-feature ones.
    faults_cfg = (
        sim.scenario.faults
        if sim.scenario is not None and sim.scenario.faults is not None
        else None
    )
    byz_cfg = faults_cfg.byzantine if faults_cfg is not None else None
    ch_cfg = faults_cfg.channel if faults_cfg is not None else None
    corrupt_on = ch_cfg is not None and ch_cfg.corrupt > 0.0
    rb_cfg = (
        sim.robust
        if sim.robust is not None and sim.robust.any_active else None
    )
    quar_cfg = rb_cfg.quarantine if rb_cfg is not None else None
    robust_on = (
        byz_cfg is not None or ch_cfg is not None or rb_cfg is not None
    )
    quarantined_commits = 0

    scen = ScenarioEngine(sim.scenario, W) if sim.scenario is not None else None
    if scen is not None:
        plan_all = scen.draw_all(
            sim.rounds,
            shard_sizes=[len(s) for s in env.shards],
            train_len=len(env.task.y_train),
        )
    else:
        plan_all = ScenarioPlan.full(sim.rounds, W)

    shard_x, shard_y = zip(*(env.shard_xy(w) for w in range(W)))
    state = env.fleet.init_state(
        env.base_params, list(shard_x), list(shard_y),
        sharding=state_sharding,
    )
    if sim.resident_momentum:
        env.fleet.init_momentum(state)

    batch = sim.batch_size
    pad_a = max(
        plan_steps(len(env.shards[w]), batch, sim.local_epochs)
        for w in range(W)
    )
    pad_b = max(
        plan_steps(len(env.shards[w]), batch, (1 - sim.beta) * sim.local_epochs)
        for w in range(W)
    )
    K_pad = sim.round_fusion if sim.round_fusion > 0 else (
        sim.prune_interval if adapt else 8
    )
    K_pad = max(1, min(K_pad, sim.rounds))

    global_params = {k: np.asarray(v) for k, v in env.base_params.items()}
    global_dev = {k: jnp.asarray(v) for k, v in global_params.items()}
    sizes_dev = jnp.asarray(np.asarray(state.shard_sizes, np.int32))
    # DGC residual accumulators live on device, carried across chunks like
    # the momentum stacks ({} when DGC is off: an empty pytree)
    dgc_res_dev = (
        {
            k: jnp.zeros((W,) + tuple(s), jnp.float32)
            for k, s in env.base_shapes.items()
        }
        if use_dgc else {}
    )
    if use_dgc and state_sharding is not None:
        dgc_res_dev = jax.device_put(dgc_res_dev, state_sharding)

    indices = [full_index(env.space) for _ in range(W)]
    histories = [WorkerHistory() for _ in range(W)]
    pending_rates = [0.0] * W
    cig_scores = None
    interval_phis: List[List[float]] = [[] for _ in range(W)]
    prune_round_count = 0
    prune_events = []
    fused_chunks = 0

    clock = 0.0
    comm_bytes = 0.0
    server_overhead = 0.0
    acc_time, het_traj, sim_traj, upd_times = [], [], [], []
    scen_rows = []

    # channel-model cache: payload bytes + FLOPs depend on the index only
    # through per-layer retained COUNTS, so the per-(round, worker) phi math
    # collapses to a dict lookup + the exact float ops of _phi_from_shapes —
    # bit-identical values, O(distinct retentions) instead of O(R x W) host
    # shape walks
    _count_cache: Dict[tuple, tuple] = {}

    def _bytes_flops(idx) -> tuple:
        key = tuple(len(idx[name]) for name in flat.names)
        ent = _count_cache.get(key)
        if ent is None:
            shapes = subparam_shapes(idx, unit_map, base_shapes)
            ent = (
                sum(int(np.prod(s)) * 4 for s in shapes.values()),
                cnn_flops_from_shapes(shapes, sim.cnn),
            )
            _count_cache[key] = ent
        return ent

    acc_time.append((0.0, _env_accuracy(env, global_params)))
    rt_base = roundtrip_total()

    sig_shapes = tuple(
        sorted((k, tuple(v.shape)) for k, v in state.params.items())
    )
    mesh_sig = (
        (sim.fleet_axis, int(mesh.shape[sim.fleet_axis]),
         tuple(int(d.id) for d in mesh.devices.flat))
        if mesh is not None else None
    )
    rb_sig = (
        ((byz_cfg.mode, float(byz_cfg.scale), float(byz_cfg.noise_std))
         if byz_cfg is not None else None),
        (float(ch_cfg.corrupt_std) if corrupt_on else None,
         ch_cfg is not None),
        ((None if rb_cfg.clip is None else float(rb_cfg.clip),
          float(rb_cfg.trim),
          ((float(quar_cfg.threshold), int(quar_cfg.strikes),
            int(quar_cfg.probation)) if quar_cfg is not None else None))
         if rb_cfg is not None else None),
        int(sim.seed),
    )
    sig = (
        sig_shapes,
        ("fused", K_pad, pad_a, pad_b, tuple(state.xs.shape), batch,
         sim.aggregation, sim.importance, bool(sim.resident_momentum),
         float(sim.dgc_sparsity), mesh_sig, rb_sig),
        float(lam),
    )
    build = lambda: _build_chunk_fn(
        trainer, unit_map, base_shapes, flat, lam,
        by_unit=sim.aggregation == "by_unit",
        importance=sim.importance,
        resident_momentum=bool(sim.resident_momentum),
        has_phase_b=pad_b > 0,
        dgc_sparsity=float(sim.dgc_sparsity),
        mesh=mesh, fleet_axis=sim.fleet_axis,
        robust=rb_cfg, byz=byz_cfg,
        corrupt_std=float(ch_cfg.corrupt_std) if corrupt_on else None,
        channel=ch_cfg is not None, noise_seed=int(sim.seed),
        fleet_w=W if mesh is not None else None,
    )
    # quarantine health carry: full-fleet [W] rows, replicated on the mesh
    health_dev = (
        {"strikes": jnp.zeros(W, jnp.int32), "quar": jnp.zeros(W, jnp.int32)}
        if quar_cfg is not None else {}
    )

    t = 0
    while t < sim.rounds:
        # ---- chunk-start churn (host): replaced slots restart fresh ------
        ev0 = plan_all.events[t]
        if ev0.joined.any():
            for w in np.flatnonzero(ev0.joined):
                w = int(w)
                indices[w] = full_index(env.space)
                histories[w] = WorkerHistory()
                pending_rates[w] = 0.0
                interval_phis[w] = []
                env.shards[w] = plan_all.fresh_shards[t][w]
                env.fleet.update_shard(state, w, *env.shard_xy(w))
                if sim.resident_momentum:
                    state.momentum = {
                        k: v.at[w].set(0.0) for k, v in state.momentum.items()
                    }
                if use_dgc:     # fresh slot: no carried residual
                    dgc_res_dev = {
                        k: v.at[w].set(0.0) for k, v in dgc_res_dev.items()
                    }
        # ---- FedDST mask readjustment at the chunk boundary (host).  The
        # chunk-extent cut below guarantees a regrow round is always round
        # t+1 of some chunk, so the shared host step runs here and the chunk
        # simply starts from the readjusted presence rows.  Params need no
        # touch-up (the in-scan broadcast-back re-masks them); momentum rows
        # must drop newly-removed units explicitly when resident.
        if _regrow_round(sim, t + 1):
            regrown = _regrow_step(sim, env, global_params, indices, t + 1)
            for w, idx_w in regrown:
                prune_events.append((
                    t + 1, int(w),
                    {k: tuple(map(int, v)) for k, v in idx_w.items()},
                ))
            if regrown and sim.resident_momentum:
                pres_now = jnp.asarray(np.stack([
                    presence_from_index(indices[w], flat) for w in range(W)
                ]))
                m_now = masks_from_presence(
                    pres_now, flat, unit_map, base_shapes
                )
                state.momentum = {
                    k: v * m_now[k] for k, v in state.momentum.items()
                }
        # ---- chunk extent: learning events, churn, regrow and capability
        # drift rounds cut.  A drift-change round must be the LAST round of
        # its chunk (the cut fires when the PREVIOUS round drifted), so the
        # drift-triggered re-learning runs at the chunk boundary exactly
        # where the lazy loop runs it.  Outage/skip rounds do NOT cut —
        # they ride in-scan as dead rounds (real=False).
        n = min(K_pad, sim.rounds - t)
        if adapt:
            n = min(n, sim.prune_interval - (t % sim.prune_interval))
        for j in range(1, n):
            if (plan_all.events[t + j].joined.any()
                    or _regrow_round(sim, t + j + 1)
                    or (scen is not None and scen.drift_changed(t + j))):
                n = j
                break
        rounds_this = list(range(t + 1, t + n + 1))

        # ---- host pre-compute: plans / budgets / jitter, in the lazy
        # loop's exact env.rng order (plans then jitter, per round) --------
        plans_a = np.zeros((K_pad, W, pad_a, batch), np.int64)
        valid_a = np.zeros((K_pad, W, pad_a), np.float32)
        plans_b = np.zeros((K_pad, W, max(pad_b, 1), batch), np.int64)
        valid_b = np.zeros((K_pad, W, max(pad_b, 1)), np.float32)
        budgets = np.zeros((K_pad, W), np.int32)
        prune_any = np.zeros((K_pad,), bool)
        real = np.zeros((K_pad,), bool)
        weights = np.zeros((K_pad, W), np.float32)
        submit_m = np.zeros((K_pad, W), np.float32)
        mult_m = np.zeros((K_pad, W), np.float32)
        byz_m = np.zeros((K_pad, W), bool)
        cor_m = np.zeros((K_pad, W), bool)
        rnd_arr = np.zeros((K_pad,), np.int32)
        jitters = np.ones((K_pad, W))
        recov = np.zeros((K_pad, W), np.float32)
        drmat = np.ones((K_pad, W))
        steps_a = np.zeros((K_pad, W), np.int64)
        steps_b = np.zeros((K_pad, W), np.int64)
        active_list: List[List[int]] = []
        prune_now_rounds: List[np.ndarray] = []

        for j, rnd in enumerate(rounds_this):
            ev = plan_all.events[rnd - 1]
            active_ws = [int(w) for w in np.flatnonzero(ev.active)]
            active_list.append(active_ws)
            if scen is not None:
                scen_rows.append((
                    rnd, len(active_ws),
                    int(ev.dropped.sum()), int(ev.joined.sum()),
                ))
            # crash recovery rides the scan: a 1.0 in recov[j, w] zeroes the
            # worker's momentum/DGC-residual rows at the top of round j's
            # scan step — the in-scan mirror of the lazy loop's host-side
            # zero_momentum_rows/residual reset.  Applies on skip rounds too
            # (the lazy loop does its recovery bookkeeping before skipping).
            if ev.recovered is not None:
                recov[j] = ev.recovered.astype(np.float32)
            if (scen is not None and scen.cfg.faults is not None
                    and scen.cfg.faults.drift is not None):
                drmat[j] = scen.drift_mults(rnd)
            if ev.skip:
                # degraded-floor round: rides the scan as a dead round
                # (real=False, all-zero valid/submitters → the global carry
                # passes through untouched).  The lazy skip branch draws no
                # plans/jitter and resets no pending rates, so neither does
                # this one: zero env.rng draws either way.
                prune_now_rounds.append(np.zeros(W, bool))
                continue
            pa: List[Optional[np.ndarray]] = [None] * W
            pb: List[Optional[np.ndarray]] = [None] * W
            pn = np.zeros(W, bool)
            for w in active_ws:
                rate = pending_rates[w] if adapt else 0.0
                if adapt and rate > 0.0:
                    e1 = sim.beta * sim.local_epochs
                    e2 = (1 - sim.beta) * sim.local_epochs
                    pn[w] = True
                else:
                    e1, e2 = sim.local_epochs, 0.0
                nsh = len(env.shards[w])
                pa[w] = make_batch_plan(nsh, batch, e1, env.rng)
                pb[w] = make_batch_plan(nsh, batch, e2, env.rng)
                steps_a[j, w] = pa[w].shape[0]
                steps_b[j, w] = pb[w].shape[0]
            prune_now_rounds.append(pn)
            for w in active_ws:
                if pn[w]:
                    budgets[j, w] = prune_budget_units(
                        indices[w], pending_rates[w], env.space
                    )
            prune_any[j] = bool(pn.any())
            sa = stack_batch_plans(pa, num_rows=W, num_steps=pad_a)
            if sa is not None:
                plans_a[j], valid_a[j] = sa
            if pad_b > 0:
                sb = stack_batch_plans(pb, num_rows=W, num_steps=pad_b)
                if sb is not None:
                    plans_b[j], valid_b[j] = sb
            submit_m[j] = ev.submitters.astype(np.float32)
            # commit multiplicity: submitters x delivery x duplication.  With
            # no channel this IS the submitter indicator, so the f64 division
            # below matches the pre-feature weights bit-for-bit.
            mult_j = ev.submitters.astype(np.float64)
            if ev.delivered is not None:
                mult_j = mult_j * ev.delivered * (1.0 + ev.dup)
            mult_m[j] = mult_j.astype(np.float32)
            if sim.aggregation != "by_unit":
                ms = mult_j.sum()
                if ms > 0:
                    weights[j] = (mult_j / ms).astype(np.float32)
            if ev.byz is not None:
                byz_m[j] = ev.byz & ev.submitters
            if corrupt_on and ev.corrupt is not None:
                cor_m[j] = ev.corrupt & ev.delivered & ev.submitters
            rnd_arr[j] = rnd
            real[j] = True
            if sim.time_jitter > 0:
                for w in active_ws:
                    jitters[j, w] = float(
                        np.exp(env.rng.normal(0, sim.time_jitter))
                    )
            for w in active_ws:      # submission resets the pending rate
                pending_rates[w] = 0.0

        orders_np = None
        if sim.importance in STATIC_METHODS:
            orders_np = _static_orders(sim, env, flat, cig_scores,
                                       prune_round_count)
        orders_dev = jnp.asarray(
            orders_np if orders_np is not None
            else np.zeros((W, U), np.int32)
        )
        presence_dev = jnp.asarray(
            np.stack([presence_from_index(indices[w], flat) for w in range(W)])
        )
        per_round = {
            "plan_a": jnp.asarray(plans_a.astype(np.int32)),
            "valid_a": jnp.asarray(valid_a),
            "budgets": jnp.asarray(budgets),
            "prune_any": jnp.asarray(prune_any),
            "real": jnp.asarray(real),
            "weights": jnp.asarray(weights),
            "submitters": jnp.asarray(submit_m),
            "recov": jnp.asarray(recov),
        }
        if pad_b > 0:
            per_round["plan_b"] = jnp.asarray(plans_b.astype(np.int32))
            per_round["valid_b"] = jnp.asarray(valid_b)
        if robust_on:
            per_round["mult"] = jnp.asarray(mult_m)
            per_round["rnd"] = jnp.asarray(rnd_arr)
            if byz_cfg is not None:
                per_round["byz"] = jnp.asarray(byz_m)
            if corrupt_on:
                per_round["corrupt"] = jnp.asarray(cor_m)
        momentum_arg = state.momentum if sim.resident_momentum else {}

        # ---- ONE device dispatch for the whole chunk ---------------------
        (state.params, mom_out, _, global_dev, dgc_res_dev, health_dev,
         pres_seq, glob_seq, kept_seq, total_seq, quar_seq) = (
            trainer._call_cached(
                sig, build,
                state.params, momentum_arg, presence_dev, global_dev,
                dgc_res_dev, health_dev, state.xs, state.ys, sizes_dev,
                per_round, orders_dev,
            )
        )
        if sim.resident_momentum:
            state.momentum = mom_out
        fused_chunks += 1
        env.fleet.batched_calls += 1
        env.fleet.buckets_used.add(W)

        pres_seq_np = np.asarray(pres_seq)                     # [K, W, U]
        glob_seq_np = {k: np.asarray(v) for k, v in glob_seq.items()}
        if use_dgc:                                            # [K, W] ints
            kept_np = np.asarray(kept_seq)
            total_np = np.asarray(total_seq)
        if quar_cfg is not None:                               # [K, W] 0/1
            quar_np = np.asarray(quar_seq)

        # ---- post-chunk host accounting (payloads, clock, ledger, eval) --
        for j, rnd in enumerate(rounds_this):
            ev = plan_all.events[rnd - 1]
            active_ws = active_list[j]
            pn = prune_now_rounds[j]
            if ev.skip:
                # degraded floor: the global is untouched (dead scan round),
                # the virtual clock waits out the straggler deadline, no
                # update times land.  Evals still fire — glob_seq[j] is the
                # pass-through carry, identical to the lazy skip branch's
                # unchanged global_params.
                clock += _skip_round_time(env, scen, indices, rnd)
                upd_times.append([float("nan")] * W)
                if rnd % sim.eval_every == 0:
                    g_j = {k: v[j] for k, v in glob_seq_np.items()}
                    acc_time.append((clock, _env_accuracy(env, g_j)))
                continue
            for w in active_ws:     # ledger phase A at the pre-prune index
                env.account_train(indices[w], int(steps_a[j, w]))
            for w in active_ws:
                if pn[w]:
                    indices[w] = index_from_presence(pres_seq_np[j, w], flat)
                    prune_events.append((
                        rnd, int(w),
                        {k: tuple(map(int, v)) for k, v in indices[w].items()},
                    ))
                    if pad_b > 0:   # ledger phase B at the pruned index
                        env.account_train(indices[w], int(steps_b[j, w]))
            if quar_cfg is not None:
                # commits excluded by the server this round: quarantined row
                # AND a payload actually arrived (mult > 0)
                quarantined_commits += int(
                    ((quar_np[j] > 0.5) & (mult_m[j] > 0)).sum()
                )
            phis = np.full(W, np.nan)
            for w in active_ws:
                bytes_w, flops_w = _bytes_flops(indices[w])
                # the host path's exact DGC payload factor, rebuilt from the
                # realized on-device kept/total integers (submitters only —
                # non-submitters pay full price, matching _run_sync)
                pf = 1.0
                if use_dgc and ev.submitters[w]:
                    pf = 1.25 * float(kept_np[j, w]) / max(
                        float(total_np[j, w]), 1.0
                    )
                # jitter x drift multiplied HERE (one float product) so the
                # value is bit-identical to the lazy path's
                # phi_from_cost(..., jmult * time_mult); channel retries
                # stretch the drift factor FIRST (d*r), then jitter — the
                # masked loop associates its floats the same way.
                retry_mult = 1.0
                if (ch_cfg is not None and ev.retries is not None
                        and ev.submitters[w]):
                    retry_mult = (
                        1.0 + ch_cfg.retry_backoff * float(ev.retries[w])
                    )
                phi_w = env.phi_from_cost(
                    w, bytes_w, flops_w, pf,
                    jitters[j, w] * (drmat[j, w] * retry_mult),
                )
                phis[w] = phi_w
                interval_phis[w].append(phi_w)
                if ev.submitters[w]:
                    extra = 0.0
                    if ch_cfg is not None and ev.retries is not None:
                        extra = (
                            float(ev.retries[w])
                            + float(ev.dup[w] & ev.delivered[w])
                        ) * pf * bytes_w
                    comm_bytes += 2.0 * pf * bytes_w + extra
            sub_phis = phis[ev.submitters]
            round_time = float(sub_phis.max())
            if ev.dropped.any() and scen is not None:
                round_time *= scen.cfg.timeout_factor
            clock += round_time
            upd_times.append(list(phis))
            het_traj.append((rnd, heterogeneity_from_times(sub_phis)))
            if W > 3:
                sim_traj.append((rnd, similarity(indices[1], indices[3])))
            if rnd % sim.eval_every == 0:
                g_j = {k: v[j] for k, v in glob_seq_np.items()}
                acc_time.append((clock, _env_accuracy(env, g_j)))
        global_params = {k: np.array(v[n - 1]) for k, v in glob_seq_np.items()}
        t += n

        # ---- learning event at the chunk boundary (host Newton math).
        # Drift-change rounds always cut their chunk (see the extent rule),
        # so a drift-triggered re-learning fires HERE, exactly one round
        # after the capability changed — same timing as the lazy loop.
        drift_now = scen is not None and scen.drift_changed(t)
        if adapt and (t % sim.prune_interval == 0 or drift_now):
            t0 = _time.perf_counter()
            prune_round_count += 1
            if cig_scores is None and sim.importance == "cig_bnscalor":
                cig_scores = METHODS["cig_bnscalor"](ImportanceContext(
                    unit_counts=env.space.unit_counts,
                    scales=extract_bn_scales(global_params, sim.cnn),
                ))
            if drift_now:
                histories[sim.scenario.faults.drift.worker].invalidate()
            mults = scen.drift_mults(t) if scen is not None else np.ones(W)
            gammas_now = [retention(indices[w], env.space) for w in range(W)]
            phis_now = [
                float(np.mean(interval_phis[w])) if interval_phis[w]
                else env.phi_from_index(
                    w, indices[w], jitter=False, time_mult=float(mults[w])
                )
                for w in range(W)
            ]
            for w in range(W):
                histories[w].record(gammas_now[w], phis_now[w])
            if sim.fixed_pruned_rates is not None:
                k = prune_round_count - 1
                rates = (
                    sim.fixed_pruned_rates[k]
                    if k < len(sim.fixed_pruned_rates)
                    else [0.0] * W
                )
            else:
                rates = learn_pruned_rates(
                    histories, gammas_now, phis_now, sim.rate_cfg
                )
            pending_rates = list(rates)
            interval_phis = [[] for _ in range(W)]
            server_overhead += _time.perf_counter() - t0

    host_roundtrips = roundtrip_total() - rt_base
    final_costs = [env.cost_for_index(indices[w]) for w in range(W)]
    return _finalize(
        sim, env, acc_time, het_traj, sim_traj, upd_times,
        [retention(indices[w], env.space) for w in range(W)],
        [extract_subparams(global_params, indices[w], unit_map)
         for w in range(W)],
        comm_bytes, server_overhead, clock,
        global_params=global_params, host_roundtrips=host_roundtrips,
        scenario_rounds=scen_rows,
        flops_per_image_final=float(np.mean([c[0] for c in final_costs])),
        blocks_per_image_final=float(np.mean([c[2] for c in final_costs])),
        prune_events=prune_events, fused_chunks=fused_chunks,
        fault_ledger={
            **fault_ledger(plan_all.events),
            "quarantined_commits": quarantined_commits,
        },
    )


# ---------------------------------------------------------------------------
# fused ASYNC engine: the discrete-event loop itself as lax.scan chunks
# ---------------------------------------------------------------------------

def split_time_keys(finishes: np.ndarray):
    """Split float64 finish times into two float32 sort keys.

    ``hi`` is the f32 rounding of the time, ``lo`` the f64 residual cast to
    f32; because f32 rounding is monotone, ``(hi, lo)`` lexicographic order
    equals f64 order except for residual-level collisions (~2^-48 apart),
    which the fused driver's runtime order check turns into a hard error
    instead of a silent reorder."""
    hi = finishes.astype(np.float32)
    lo = (finishes - hi.astype(np.float64)).astype(np.float32)
    return hi, lo


def async_pop_perm(fin_hi, fin_lo, rows):
    """Device pending-queue pop order: the sorted-finish-times replacement
    for the host ``heapq`` pop.  A stable ``lexsort`` over (primary) the
    split finish keys then (tertiary) the worker index reproduces the host
    heap's ``(time, worker_index)`` tuple ordering exactly — ties in finish
    time pop in ascending worker order.  Padding slots carry ``hi = +inf``
    so they sort to the tail."""
    return jnp.lexsort((rows, fin_lo, fin_hi))


def _build_async_chunk_fn(trainer, unit_map, base_shapes, lam, *, method, W,
                          BP, EB, cohort_size, fedasync_a, lr,
                          dcasgd_lambda, dcasgd_m,
                          clip_norm=None, quarantine=None):
    """Build the jitted async chunk program: ``lax.scan`` over KB window
    batches, each popping its events from a device queue, training the
    batch's workers as one vmapped sub-stack, then walking the commits
    through an inner scan of ``async_commit_jnp`` merges.

    Carry: (fetched ``[W, ...]`` snapshots, global params, server version,
    per-slot ``fetched_ver``, dcasgd backup/accumulator).  Per-batch inputs
    arrive in heap PUSH order; outputs are the popped worker order and
    staleness integers (the host verifies both against the plan) plus the
    post-commit globals captured at eval events."""
    train_one = trainer.make_resident_train(unit_map, lam)
    vm_train = jax.vmap(
        lambda p, x, y, plan, valid, mask, gl:
            train_one(p, x, y, plan, valid, mask, gl)
    )
    gl_base = group_size_sqrt_from_shapes(base_shapes, unit_map)

    def chunk(fetched, g, version, fetched_ver, backup, dc_m, health, xs, ys,
              per_batch):
        # async workers never prune: masks are all-ones, group-lasso factors
        # are the base-shape constants
        masks = {
            k: jnp.ones((BP,) + tuple(base_shapes[k]), jnp.float32)
            for k in fetched
        }
        gl = {
            lname: jnp.full((BP,), s, jnp.float32)
            for lname, s in gl_base.items()
        }

        def commit_body(c, e):
            g, version, fetched_ver, fetched, backup, dc_m, health, eval_buf = c
            w, v_ok, drop, t_row, f_row, ref_row, ev_flag, ev_slot = e
            s = version - fetched_ver[w]
            live = v_ok * (1.0 - drop)     # merged = real AND not timed out
            g2, backup2, dc_m2 = async_commit_jnp(
                method, g, t_row, f_row, s, w, backup, dc_m,
                cohort_size=cohort_size, fedasync_a=fedasync_a, lr=lr,
                dcasgd_lambda=dcasgd_lambda, dcasgd_m=dcasgd_m,
                clip_norm=clip_norm,
            )
            keep = live > 0
            if quarantine is not None:
                # per-commit MAD-outlier health: only LIVE commits touch the
                # tracker (dropped/padding slots must not move the median
                # population), and a rejected commit keeps the global but
                # still bumps the version below — the pre-planned version
                # trajectory is fixed.
                hk = live > 0
                delta = {k: t_row[k] - f_row[k] for k in t_row}
                norm = delta_norms_jnp(
                    {k: d[None] for k, d in delta.items()}
                )[0]
                reject, st2, qu2, nm2, sn2 = async_health_step_jnp(
                    norm, w, health["strikes"], health["quar"],
                    health["norms"], health["seen"],
                    threshold=quarantine.threshold,
                    strikes_needed=quarantine.strikes,
                    probation=quarantine.probation,
                )
                health = {
                    "strikes": jnp.where(hk, st2, health["strikes"]),
                    "quar": jnp.where(hk, qu2, health["quar"]),
                    "norms": jnp.where(hk, nm2, health["norms"]),
                    "seen": jnp.where(hk, sn2, health["seen"]),
                    "rejected": health["rejected"]
                    + (hk & reject).astype(jnp.int32),
                }
                keep = hk & ~reject
            g = {k: jnp.where(keep, g2[k], g[k]) for k in g}
            backup = {k: jnp.where(keep, backup2[k], backup[k]) for k in backup}
            dc_m = {k: jnp.where(keep, dc_m2[k], dc_m[k]) for k in dc_m}
            version = version + live.astype(jnp.int32)
            # refetch AFTER the bump: dropped commits refetch the unchanged
            # global; padding slots (v_ok = 0) touch nothing
            ref_eff = ref_row * v_ok
            fetched = refetch_rows_jnp(fetched, ref_eff, g)
            fetched_ver = jnp.where(ref_eff > 0, version, fetched_ver)
            wr = (ev_flag * v_ok) > 0
            eval_buf = {
                k: eval_buf[k].at[ev_slot].set(
                    jnp.where(wr, g[k], eval_buf[k][ev_slot])
                )
                for k in eval_buf
            }
            return (g, version, fetched_ver, fetched, backup, dc_m, health,
                    eval_buf), (w, s)

        def body(carry, inp):
            fetched, g, version, fetched_ver, backup, dc_m, health = carry
            # device queue pop: push-ordered events -> commit order
            perm = async_pop_perm(inp["fin_hi"], inp["fin_lo"], inp["rows"])
            rows = jnp.take(inp["rows"], perm)
            valid = jnp.take(inp["valid"], perm)
            dropped = jnp.take(inp["dropped"], perm)
            plans = jnp.take(inp["plans"], perm, axis=0)
            pvalid = jnp.take(inp["pvalid"], perm, axis=0)
            refetch = jnp.take(inp["refetch"], perm, axis=0)
            eval_flag = jnp.take(inp["eval_flag"], perm)
            eval_slot = jnp.take(inp["eval_slot"], perm)
            # masked gather-in of each popped worker's fetched snapshot +
            # shard, then ONE vmapped bucket-sized training for the batch
            # (within a batch every worker is distinct and its input was
            # fixed at its last refetch, so batched training is exact)
            p0 = {k: jnp.take(v, rows, axis=0) for k, v in fetched.items()}
            xb = jnp.take(xs, rows, axis=0)
            yb = jnp.take(ys, rows, axis=0)
            trained, _, _ = vm_train(p0, xb, yb, plans, pvalid, masks, gl)
            eval_buf = {
                k: jnp.zeros((EB,) + tuple(base_shapes[k]), jnp.float32)
                for k in g
            }
            (g, version, fetched_ver, fetched, backup, dc_m, health,
             eval_buf), (
                order, stale
            ) = jax.lax.scan(
                commit_body,
                (g, version, fetched_ver, fetched, backup, dc_m, health,
                 eval_buf),
                (rows, valid, dropped, trained, p0, refetch, eval_flag,
                 eval_slot),
            )
            return (fetched, g, version, fetched_ver, backup, dc_m,
                    health), (order, stale, eval_buf)

        carry0 = (fetched, g, version, fetched_ver, backup, dc_m, health)
        (fetched, g, version, fetched_ver, backup, dc_m, health), (
            order_seq, stale_seq, eval_seq
        ) = jax.lax.scan(body, carry0, per_batch)
        return (fetched, g, version, fetched_ver, backup, dc_m, health,
                order_seq, stale_seq, eval_seq)

    return jax.jit(chunk)


def run_async_fused(sim, env, scen, participants, plan):
    """Async simulation with the fused event-queue engine (see module doc).

    Replays the SAME pre-simulated ``AsyncEventPlan`` as the resident/
    per-worker engines (``simulation._run_async`` builds it and routes
    here), so commit order, staleness weights, dropout outcomes and virtual
    clocks are identical by construction; chunks of ``round_fusion`` window
    batches run as one device program each."""
    from .simulation import _env_accuracy, _finalize   # lazy: no import cycle

    validate_fused_config(sim)
    W = sim.num_workers
    method = sim.method
    lam = sim.lam
    trainer = env.trainer
    unit_map = env.unit_map
    base_shapes = env.base_shapes
    n_part = len(participants)
    idx = full_index(env.space)
    # robust layer (async half): norm clip + quarantine; trim was rejected
    # by name in _run_async before routing here
    rb_cfg = (
        sim.robust if sim.robust is not None and sim.robust.any_active
        else None
    )
    clip_norm = rb_cfg.clip if rb_cfg is not None else None
    quar_cfg = rb_cfg.quarantine if rb_cfg is not None else None

    global_params = {k: np.asarray(v) for k, v in env.base_params.items()}
    acc_time = [(0.0, _env_accuracy(env, global_params))]
    rt_base = roundtrip_total()
    # async commits always move base-shape payloads (workers never prune)
    commit_bytes = 2.0 * sum(
        int(np.prod(s)) * 4 for s in base_shapes.values()
    )
    comm_bytes = 0.0
    fused_chunks = 0
    final_cost = env.cost_for_index(idx)

    E = plan.num_events
    if E == 0:
        return _finalize(sim, env, acc_time, [], [], [], [1.0] * W,
                         [dict(global_params) for _ in range(W)], 0.0, 0.0,
                         0.0, global_params=dict(global_params),
                         host_roundtrips=roundtrip_total() - rt_base,
                         scenario_rounds=(
                             [(0, n_part, 0, 0)] if scen is not None else []
                         ),
                         flops_per_image_final=final_cost[0],
                         blocks_per_image_final=final_cost[2],
                         fused_chunks=0,
                         fault_ledger={
                             **(plan.fault_ledger or {}),
                             "quarantined_commits": 0,
                         })

    shard_x, shard_y = zip(*(env.shard_xy(w) for w in range(W)))
    state = env.fleet.init_state(env.base_params, list(shard_x), list(shard_y))

    batch = sim.batch_size
    pad_steps = max(
        plan_steps(len(env.shards[w]), batch, sim.local_epochs)
        for w in participants
    )
    S_eff = max(pad_steps, 1)      # static step dim even for no-step plans
    n_batches = len(plan.batch_starts) - 1
    BP = int(np.diff(plan.batch_starts).max())
    EB = max(
        max(
            int(plan.evals[int(plan.batch_starts[b]):
                           int(plan.batch_starts[b + 1])].sum())
            for b in range(n_batches)
        ),
        1,
    )
    KB = sim.round_fusion if sim.round_fusion > 0 else 8
    KB = max(1, min(KB, n_batches))

    # eval slots: exclusive cumsum of eval flags within each batch
    slot_of = np.zeros(E, np.int64)
    for b in range(n_batches):
        s0, e0 = int(plan.batch_starts[b]), int(plan.batch_starts[b + 1])
        ev = plan.evals[s0:e0].astype(np.int64)
        slot_of[s0:e0] = np.cumsum(ev) - ev
    fin_hi_all, fin_lo_all = split_time_keys(plan.finishes)

    g_dev = {k: jnp.asarray(v, jnp.float32) for k, v in global_params.items()}
    fetched_dev = state.params     # [W, ...] broadcast of the base params
    version_dev = jnp.asarray(0, jnp.int32)
    fetched_ver_dev = jnp.zeros((W,), jnp.int32)
    if method == "dcasgd_s":
        backup_dev = dict(fetched_dev)   # per-slot w_bak starts at the global
        dc_m_dev = {k: jnp.zeros_like(v) for k, v in g_dev.items()}
    else:
        backup_dev, dc_m_dev = {}, {}
    health_dev = (
        {
            "strikes": jnp.zeros(W, jnp.int32),
            "quar": jnp.zeros(W, jnp.int32),
            "norms": jnp.zeros(W, jnp.float32),
            "seen": jnp.zeros(W, bool),
            "rejected": jnp.asarray(0, jnp.int32),
        }
        if quar_cfg is not None else {}
    )

    sig_shapes = tuple(
        sorted((k, tuple(v.shape)) for k, v in state.params.items())
    )
    rb_sig = (
        None if clip_norm is None else float(clip_norm),
        ((float(quar_cfg.threshold), int(quar_cfg.strikes),
          int(quar_cfg.probation)) if quar_cfg is not None else None),
    )
    sig = (
        sig_shapes,
        ("fused_async", method, KB, BP, S_eff, EB, tuple(state.xs.shape),
         batch, n_part, float(sim.fedasync_a), float(sim.lr),
         float(sim.dcasgd_lambda), float(sim.dcasgd_m), rb_sig),
        float(lam),
    )
    build = lambda: _build_async_chunk_fn(
        trainer, unit_map, base_shapes, lam, method=method, W=W, BP=BP,
        EB=EB, cohort_size=n_part, fedasync_a=float(sim.fedasync_a),
        lr=float(sim.lr), dcasgd_lambda=float(sim.dcasgd_lambda),
        dcasgd_m=float(sim.dcasgd_m),
        clip_norm=None if clip_norm is None else float(clip_norm),
        quarantine=quar_cfg,
    )

    b = 0
    while b < n_batches:
        nc = min(KB, n_batches - b)
        rows_a = np.zeros((KB, BP), np.int32)
        valid_a = np.zeros((KB, BP), np.float32)
        drop_a = np.zeros((KB, BP), np.float32)
        # padding slots: +inf finish keys sort them past every real event
        # (built explicitly — inf-residual arithmetic would NaN the keys)
        hi_a = np.full((KB, BP), np.inf, np.float32)
        lo_a = np.zeros((KB, BP), np.float32)
        plans_a = np.zeros((KB, BP, S_eff, batch), np.int32)
        pvalid_a = np.zeros((KB, BP, S_eff), np.float32)
        ref_a = np.zeros((KB, BP, W), np.float32)
        evf_a = np.zeros((KB, BP), np.float32)
        evs_a = np.zeros((KB, BP), np.int32)
        for j in range(nc):
            s0 = int(plan.batch_starts[b + j])
            e0 = int(plan.batch_starts[b + j + 1])
            L = e0 - s0
            # feed the device queue in heap PUSH order — the in-scan pop
            # must genuinely re-derive the commit order
            feed = s0 + np.argsort(plan.push_seq[s0:e0], kind="stable")
            rows_a[j, :L] = plan.workers[feed]
            valid_a[j, :L] = 1.0
            drop_a[j, :L] = plan.dropped[feed]
            hi_a[j, :L] = fin_hi_all[feed]
            lo_a[j, :L] = fin_lo_all[feed]
            ref_a[j, :L] = plan.refetch[feed]
            evf_a[j, :L] = plan.evals[feed]
            evs_a[j, :L] = slot_of[feed]
            for r, i in enumerate(feed):
                p = plan.plans[i]
                if p.shape[0]:
                    plans_a[j, r, :p.shape[0]] = p
                    pvalid_a[j, r, :p.shape[0]] = 1.0
        per_batch = {
            "rows": jnp.asarray(rows_a),
            "valid": jnp.asarray(valid_a),
            "dropped": jnp.asarray(drop_a),
            "fin_hi": jnp.asarray(hi_a),
            "fin_lo": jnp.asarray(lo_a),
            "plans": jnp.asarray(plans_a),
            "pvalid": jnp.asarray(pvalid_a),
            "refetch": jnp.asarray(ref_a),
            "eval_flag": jnp.asarray(evf_a),
            "eval_slot": jnp.asarray(evs_a),
        }

        # ---- ONE device dispatch for the whole chunk ---------------------
        (fetched_dev, g_dev, version_dev, fetched_ver_dev, backup_dev,
         dc_m_dev, health_dev, order_seq, stale_seq, eval_seq) = (
            trainer._call_cached(
                sig, build, fetched_dev, g_dev, version_dev, fetched_ver_dev,
                backup_dev, dc_m_dev, health_dev, state.xs, state.ys,
                per_batch,
            )
        )
        fused_chunks += 1
        env.fleet.batched_calls += 1
        env.fleet.buckets_used.add(BP)

        order_np = np.asarray(order_seq)
        stale_np = np.asarray(stale_seq)
        eval_np = {k: np.asarray(v) for k, v in eval_seq.items()}
        for j in range(nc):
            s0 = int(plan.batch_starts[b + j])
            e0 = int(plan.batch_starts[b + j + 1])
            L = e0 - s0
            # the device pop must reproduce the host heap replay exactly —
            # commit order (ties included) AND the staleness integers
            if not (
                np.array_equal(order_np[j, :L], plan.workers[s0:e0])
                and np.array_equal(stale_np[j, :L], plan.staleness[s0:e0])
            ):
                raise RuntimeError(
                    "device event queue diverged from host heap replay"
                )
            for i in range(s0, e0):
                env.account_train(idx, plan.plans[i].shape[0])
                if not plan.dropped[i]:
                    comm_bytes += commit_bytes
                if plan.evals[i]:
                    g_i = {k: eval_np[k][j, slot_of[i]] for k in eval_np}
                    acc_time.append(
                        (float(plan.clocks[i]), _env_accuracy(env, g_i))
                    )
        b += nc

    global_params = {k: np.asarray(v) for k, v in g_dev.items()}
    clock = float(plan.clocks[-1])
    host_roundtrips = roundtrip_total() - rt_base
    scen_rows = [(0, n_part, 0, 0)] if scen is not None else []
    rejected = (
        int(np.asarray(health_dev["rejected"]))
        if quar_cfg is not None else 0
    )
    return _finalize(sim, env, acc_time, [], [], [], [1.0] * W,
                     [dict(global_params) for _ in range(W)], comm_bytes, 0.0,
                     clock, global_params=dict(global_params),
                     host_roundtrips=host_roundtrips,
                     scenario_rounds=scen_rows,
                     flops_per_image_final=final_cost[0],
                     blocks_per_image_final=final_cost[2],
                     fused_chunks=fused_chunks,
                     fault_ledger={
                         **(plan.fault_ledger or {}),
                         "quarantined_commits": rejected,
                     })
