"""Pruned-rate learning (AdaptCL Algorithm 2).

The server models each worker's update time phi as a function of its model
retention ratio gamma using Newton divided-difference interpolation over the
observed history ``(gamma^0, phi^0) .. (gamma^n, phi^n)`` and *inverts* it at
the target time ``phi_min`` (the fastest worker's current update time).

Because we want ``gamma_target = f^{-1}(phi_min)``, we interpolate the inverse
directly: nodes are ``phi`` values, values are ``gamma`` values (Eq. 2 in the
paper).  The bootstrap rule (worker never pruned before) assumes
``phi = alpha * phi_now * gamma`` and yields
``P = (phi_now - phi_min) / (alpha * phi_now)`` (Alg. 2 line 9).

Pure Python/NumPy: this runs on the *server* and its cost is part of the
paper's "negligible overhead" claim (measured in benchmarks/run.py:overhead).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

__all__ = [
    "PrunedRateConfig",
    "WorkerHistory",
    "newton_divided_differences",
    "newton_eval",
    "inverse_interpolate_gamma",
    "learn_pruned_rates",
]


@dataclasses.dataclass(frozen=True)
class PrunedRateConfig:
    """Controlling parameters of Alg. 2 (Tab. I)."""

    rho_max: float = 0.5     # maximum pruned rate per pruning
    rho_min: float = 0.02    # minimum pruned rate (skip overly tiny prunings)
    gamma_min: float = 0.1   # minimum model retention ratio
    alpha: float = 2.0       # bootstrap coefficient (phi ~ alpha*phi_now*gamma)
    max_history: int = 8     # cap interpolation order (Runge guard; paper: n stays 3-4)


@dataclasses.dataclass
class WorkerHistory:
    """Per-worker record of (retention ratio, averaged update time) pairs.

    ``gammas[i]``/``phis[i]`` is the i-th *pruning checkpoint*: the retention
    ratio in force and the update time averaged over the pruning interval
    (Appendix A: averaging over the PI rounds filters bandwidth noise).
    """

    gammas: List[float] = dataclasses.field(default_factory=list)
    phis: List[float] = dataclasses.field(default_factory=list)

    def record(self, gamma: float, phi: float) -> None:
        if not np.isfinite(gamma) or not np.isfinite(phi):
            raise ValueError(f"non-finite history point ({gamma}, {phi})")
        self.gammas.append(float(gamma))
        self.phis.append(float(phi))

    @property
    def pruned_before(self) -> bool:
        # First entry is the unpruned (gamma=1.0) measurement; a worker counts
        # as "pruned before" once it has >=2 distinct retention levels.
        return len({round(g, 12) for g in self.gammas}) >= 2

    def invalidate(self) -> None:
        """Drop the history: the worker's capability changed (fault-injection
        capability drift), so every recorded (gamma, phi) pair describes a
        machine that no longer exists.  The next ``learn_pruned_rates`` call
        re-enters Alg. 2 through the bootstrap path, exactly as if the
        worker had never been profiled."""
        self.gammas.clear()
        self.phis.clear()


def newton_divided_differences(xs: Sequence[float], ys: Sequence[float]) -> np.ndarray:
    """Return Newton divided-difference coefficients c_0..c_n for nodes xs.

    ``p(x) = c_0 + c_1 (x-x_0) + ... + c_n (x-x_0)...(x-x_{n-1})``
    """
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    if xs.ndim != 1 or xs.shape != ys.shape or xs.size == 0:
        raise ValueError("xs/ys must be equal-length 1-D, non-empty")
    n = xs.size
    coef = ys.copy()
    for j in range(1, n):
        denom = xs[j:] - xs[:-j]
        if np.any(np.abs(denom) < 1e-12):
            raise ZeroDivisionError("duplicate interpolation nodes")
        coef[j:] = (coef[j:] - coef[j - 1 : -1]) / denom
    return coef


def newton_eval(coef: np.ndarray, xs: Sequence[float], x: float) -> float:
    """Horner-style evaluation of the Newton form at x."""
    xs = np.asarray(xs, dtype=np.float64)
    acc = coef[-1]
    for k in range(len(coef) - 2, -1, -1):
        acc = acc * (x - xs[k]) + coef[k]
    return float(acc)


def _dedupe_nodes(phis: Sequence[float], gammas: Sequence[float]) -> Tuple[List[float], List[float]]:
    """Drop (phi, gamma) points whose phi collides with an earlier node.

    Newton interpolation needs distinct nodes; repeated measurements at the
    same update time carry no new information, keep the latest.
    """
    seen = {}
    for p, g in zip(phis, gammas):
        seen[round(float(p), 9)] = (float(p), float(g))
    pts = sorted(seen.values(), key=lambda t: t[0])
    return [p for p, _ in pts], [g for _, g in pts]


def inverse_interpolate_gamma(
    history: WorkerHistory, phi_target: float, max_history: int = 8
) -> float:
    """gamma_target = f^{-1}(phi_target) via Newton interpolation (Eq. 2)."""
    # The Runge guard keeps the *most recent* pruning checkpoints, so the
    # history must be truncated by recency BEFORE _dedupe_nodes sorts the
    # nodes by ascending phi (sorting first would keep the largest-phi nodes
    # — stale early measurements — forever).
    phis, gammas = _dedupe_nodes(
        history.phis[-max_history:], history.gammas[-max_history:]
    )
    if len(phis) == 0:
        raise ValueError("empty history")
    if len(phis) == 1:
        # Single point: proportional model through the origin.
        return gammas[0] * phi_target / phis[0]
    coef = newton_divided_differences(phis, gammas)
    return newton_eval(coef, phis, phi_target)


def learn_pruned_rates(
    histories: Sequence[WorkerHistory],
    gammas_now: Sequence[float],
    phis_now: Sequence[float],
    cfg: PrunedRateConfig = PrunedRateConfig(),
) -> List[float]:
    """AdaptCL Algorithm 2: one pruned rate P_w in [0, rho_max] per worker.

    Args:
      histories: per-worker (gamma, phi) history *including* the current point.
      gammas_now: current retention ratio per worker.
      phis_now: current (interval-averaged) update time per worker.
    """
    W = len(histories)
    if not (W == len(gammas_now) == len(phis_now)):
        raise ValueError("length mismatch")
    phi_min = float(min(phis_now))
    rates: List[float] = []
    for w in range(W):
        gamma_now = float(gammas_now[w])
        phi_now = float(phis_now[w])
        if histories[w].pruned_before:
            gamma_target = inverse_interpolate_gamma(
                histories[w], phi_min, cfg.max_history
            )
            gamma_target = max(gamma_target, cfg.gamma_min)
            # Guard: interpolation can extrapolate wildly; never *grow* the
            # model and never cut below gamma_min.
            gamma_target = min(gamma_target, gamma_now)
            if gamma_now - gamma_target < cfg.rho_min:
                gamma_target = gamma_now  # skip tiny prunings (Alg.2 line 5-6)
            p = (gamma_now - gamma_target) / gamma_now
        else:
            # Bootstrap: phi ~= alpha * phi_now * gamma  =>  line 9.
            p = (phi_now - phi_min) / (cfg.alpha * phi_now)
        p = float(np.clip(p, 0.0, cfg.rho_max))
        # Respect gamma_min even on the bootstrap path.
        if gamma_now * (1.0 - p) < cfg.gamma_min:
            p = max(0.0, 1.0 - cfg.gamma_min / gamma_now)
        if p < cfg.rho_min:
            p = 0.0
        rates.append(p)
    return rates
