"""Fault-injection world model: scripted hostile-world events for the simulator.

AdaptCL's core claim is adaptation *without prior capability information* —
but a static world (phi fixed at init, i.i.d. dropout) never exercises the
adaptation loop.  This module scripts four fault families into the scenario
layer (``ScenarioConfig.faults``) so the prune-rate learner and every fleet
engine can be tested against a hostile, *replayable* world:

* **capability drift** (:class:`DriftConfig`) — one worker's update time
  jumps or ramps by ``factor`` at ``round`` (deterministic, zero RNG).  A
  drift change forces a prune-rate re-learning event: the server re-enters
  Alg. 2 at the end of the drift round with the drifted worker's stale
  (gamma, phi) history invalidated (``WorkerHistory.invalidate`` — the old
  measurements describe a capability that no longer exists).
* **crash / recovery** (:class:`CrashConfig`) — each online worker crashes
  per round with probability ``rate`` (drawn from the DEDICATED fault RNG
  stream), goes offline for ``outage_rounds`` rounds, and returns *stale*:
  it refetches the current global (sync: the ordinary broadcast-back),
  restarts momentum and DGC residuals, re-enters with its LAST mask, and
  spends ``recovery_rounds`` re-joining — it trains and refetches but does
  not count toward aggregation (retry/backoff accounting:
  ``SimResult.retry_total``).  Under the async schedulers a crashed commit
  delays the worker's next schedule by ``outage_rounds`` nominal update
  times; it returns against a bumped server version (larger staleness).
* **coordinated regional outage** (:class:`OutageConfig`) — a contiguous
  slot range (alignable to the mesh-sharded fleet's contiguous layout via
  :meth:`OutageConfig.for_shard` / ``scenario.shard_cohorts``) drops for a
  window of rounds.  The server degrades gracefully: if the surviving
  submitters still number >= ``min_participants`` the round aggregates the
  partial cohort (``rounds_degraded``); otherwise the round is SKIPPED —
  the virtual clock still advances by the straggler deadline, nothing
  trains, the global is untouched, and no engine hangs or raises
  (``rounds_skipped``).
* **diurnal participation wave** (:class:`WaveConfig`) — time-varying
  participation ``C(t) = C * (1 + amplitude * sin(2*pi*(t-1)/period))``
  (deterministic, zero RNG).
* **Byzantine workers** (:class:`ByzantineConfig`) — per-round compromised
  workers emit adversarial commits: the committed delta (what the worker
  submits minus the broadcast-back global it started from) is sign-flipped,
  scaled, or replaced with ``delta + noise_std * N(0, 1)`` *as a pure
  transform at the submission boundary* — training itself is honest, only
  the payload lies.  The compromised set is either a fixed ``workers``
  tuple (deterministic, zero RNG) or re-drawn per round with probability
  ``fraction`` per slot (one ``fault_rng.random(W)`` block per round).
* **lossy channel** (:class:`ChannelConfig`) — every submitted commit runs
  a delivery gauntlet: each uplink attempt fails with probability ``drop``
  and is retried up to ``max_retries`` times (each retry multiplies the
  worker's phi by ``1 + retry_backoff`` cumulatively and lands in the
  ``retry_total`` ledger); a commit whose every attempt fails is LOST
  (excluded from aggregation — the round degrades like a straggler drop
  but the worker still trained and its phi still gates the round clock).
  Delivered commits are duplicated with probability ``dup`` (double
  multiplicity under plain mean; the robust layer dedupes) and corrupted
  with probability ``corrupt`` (payload garbled by ``corrupt_std`` noise).
  One fixed draw block per round — ``random((W, max_retries + 1))`` then
  ``random(W)`` twice — regardless of who submits, so the stream never
  depends on cohort outcomes.

**Engine-identical by construction.**  Deterministic families (drift,
outage, wave) are pure functions of (config, round); the stochastic family
(crash) draws from a dedicated fault RNG stream
(``ScenarioEngine.fault_rng``, seeded ``cfg.seed + 40961``) consumed once
per round in round order — so the lazy sync loop, ``draw_all``'s pre-drawn
plan, and the async event planner all replay the identical fault stream,
and a ``faults=None`` run consumes ZERO extra draws on every stream
(bit-identical to the pre-feature simulator, pinned by
``tests/test_faults.py``).

The per-round outcome rides on :class:`scenario.RoundEvents` (``offline``,
``recovered``, ``recovering``, ``drift_mult``, ``skip``, ``degraded``
fields, all ``None``/``False`` when faults are off), and the run-level
fault ledger (``SimResult.drift_events`` / ``rounds_degraded`` /
``rounds_skipped`` / ``workers_recovered`` / ``retry_total``) is computed
by :func:`fault_ledger` from the events alone — one shared pure function,
so sequential / masked / fused ledgers cannot diverge.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from .timing import drift_multiplier

__all__ = [
    "ByzantineConfig",
    "ChannelConfig",
    "CrashConfig",
    "DriftConfig",
    "FaultConfig",
    "OutageConfig",
    "WaveConfig",
    "fault_ledger",
]


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """One worker's capability drifts: phi multiplies by ``factor``.

    ``mode="jump"`` switches at ``round``; ``mode="ramp"`` interpolates the
    multiplier linearly over ``ramp_rounds`` rounds starting at ``round``.
    ``factor > 1`` = the worker got slower, ``< 1`` = faster."""

    worker: int = 0
    round: int = 1          # first round the drifted capability is in force
    factor: float = 2.0     # update-time multiplier after the drift
    mode: str = "jump"      # "jump" | "ramp"
    ramp_rounds: int = 1

    def __post_init__(self):
        if self.worker < 0:
            raise ValueError(f"drift worker {self.worker} must be >= 0")
        if self.round < 1:
            raise ValueError(f"drift round {self.round} must be >= 1")
        if not (self.factor > 0.0):
            raise ValueError(f"drift factor {self.factor} must be > 0")
        if self.mode not in ("jump", "ramp"):
            raise ValueError(f"drift mode {self.mode!r} not in jump/ramp")
        if self.ramp_rounds < 1:
            raise ValueError(
                f"drift ramp_rounds {self.ramp_rounds} must be >= 1"
            )

    def mult_at(self, round_t: int) -> float:
        """Update-time multiplier in force at 1-based round ``round_t``."""
        return drift_multiplier(
            round_t, self.round, self.factor,
            ramp_rounds=self.ramp_rounds if self.mode == "ramp" else 1,
        )


@dataclasses.dataclass(frozen=True)
class CrashConfig:
    """Per-round worker crashes with offline span + staged recovery."""

    rate: float = 0.05        # P(online worker crashes this round)
    outage_rounds: int = 2    # rounds fully offline after a crash
    recovery_rounds: int = 1  # re-join rounds: train + refetch, no aggregation

    def __post_init__(self):
        if not (0.0 <= self.rate < 1.0):
            raise ValueError(f"crash rate {self.rate} outside [0, 1)")
        if self.outage_rounds < 1:
            raise ValueError(
                f"crash outage_rounds {self.outage_rounds} must be >= 1"
            )
        if self.recovery_rounds < 0:
            raise ValueError(
                f"crash recovery_rounds {self.recovery_rounds} must be >= 0"
            )


@dataclasses.dataclass(frozen=True)
class OutageConfig:
    """A contiguous slot range offline for rounds [start, start + length)."""

    start: int = 1        # first affected round (1-based)
    length: int = 1       # rounds the region stays dark
    slot_lo: int = 0      # first affected worker slot
    slot_hi: int = 1      # one past the last affected slot

    def __post_init__(self):
        if self.start < 1:
            raise ValueError(f"outage start {self.start} must be >= 1")
        if self.length < 1:
            raise ValueError(f"outage length {self.length} must be >= 1")
        if not (0 <= self.slot_lo < self.slot_hi):
            raise ValueError(
                f"outage slots [{self.slot_lo}, {self.slot_hi}) must be a "
                "non-empty ascending range"
            )

    @staticmethod
    def for_shard(
        start: int, length: int, shard: int, num_workers: int, num_shards: int
    ) -> "OutageConfig":
        """Outage covering mesh shard ``shard``'s contiguous slot range.

        Matches the mesh-sharded fleet's layout (shard ``s`` owns slots
        ``[s * W_local, (s+1) * W_local)`` — the same algebra as
        ``scenario.shard_cohorts`` / ``fleet.global_to_shard_local``), so a
        "regional" outage takes out exactly one shard's row block."""
        if num_shards < 1 or num_workers % num_shards:
            raise ValueError(
                f"num_workers={num_workers} does not divide into "
                f"{num_shards} shards"
            )
        if not (0 <= shard < num_shards):
            raise ValueError(f"shard {shard} outside [0, {num_shards})")
        w_local = num_workers // num_shards
        return OutageConfig(
            start=start, length=length,
            slot_lo=shard * w_local, slot_hi=(shard + 1) * w_local,
        )

    def covers(self, round_t: int) -> bool:
        return self.start <= round_t < self.start + self.length


@dataclasses.dataclass(frozen=True)
class WaveConfig:
    """Diurnal participation wave: C(t) = C * (1 + amp * sin(2pi (t-1)/T))."""

    amplitude: float = 0.5
    period: int = 8

    def __post_init__(self):
        if not (0.0 < self.amplitude < 1.0):
            raise ValueError(
                f"wave amplitude {self.amplitude} outside (0, 1)"
            )
        if self.period < 2:
            raise ValueError(f"wave period {self.period} must be >= 2")

    def factor_at(self, round_t: int) -> float:
        return float(
            1.0 + self.amplitude * np.sin(
                2.0 * np.pi * (round_t - 1) / self.period
            )
        )


@dataclasses.dataclass(frozen=True)
class ByzantineConfig:
    """Compromised workers emit adversarial commits.

    The attack is a pure transform on the committed delta at the submission
    boundary: ``sign_flip`` sends ``-delta``, ``scale`` sends
    ``scale * delta`` (negative scale = sign-flip-and-amplify), ``noise``
    sends ``delta + noise_std * N(0, 1)`` (masked to the worker's live
    coordinates).  ``workers`` fixes the compromised slot set
    (deterministic, zero RNG); ``workers=None`` re-draws the set per round
    with probability ``fraction`` per slot from the fault RNG."""

    workers: Optional[Sequence[int]] = None
    fraction: float = 0.0
    mode: str = "sign_flip"   # "sign_flip" | "scale" | "noise"
    scale: float = -10.0      # multiplier for mode="scale"
    noise_std: float = 1.0    # std for mode="noise"

    def __post_init__(self):
        if self.workers is not None:
            ws = tuple(int(w) for w in self.workers)
            if not ws or any(w < 0 for w in ws):
                raise ValueError(
                    f"byzantine workers {self.workers!r} must be a "
                    "non-empty sequence of slots >= 0"
                )
            object.__setattr__(self, "workers", ws)
        if not (0.0 <= self.fraction <= 1.0):
            raise ValueError(
                f"byzantine fraction {self.fraction} outside [0, 1]"
            )
        if self.mode not in ("sign_flip", "scale", "noise"):
            raise ValueError(
                f"byzantine mode {self.mode!r} not in sign_flip/scale/noise"
            )
        if self.mode == "scale" and self.scale == 0.0:
            raise ValueError("byzantine scale must be nonzero")
        if not (self.noise_std > 0.0):
            raise ValueError(
                f"byzantine noise_std {self.noise_std} must be > 0"
            )


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Lossy uplink: drop/retry/backoff, duplicate delivery, corruption."""

    drop: float = 0.0          # P(one delivery attempt fails)
    dup: float = 0.0           # P(a delivered commit arrives twice)
    corrupt: float = 0.0       # P(a delivered payload is garbled)
    max_retries: int = 2       # extra attempts after the first failure
    retry_backoff: float = 0.5  # phi multiplier grows by this per retry
    corrupt_std: float = 10.0  # noise std applied to corrupted payloads

    def __post_init__(self):
        for field in ("drop", "dup", "corrupt"):
            v = getattr(self, field)
            if not (0.0 <= v < 1.0):
                raise ValueError(f"channel {field} {v} outside [0, 1)")
        if self.max_retries < 0:
            raise ValueError(
                f"channel max_retries {self.max_retries} must be >= 0"
            )
        if self.retry_backoff < 0.0:
            raise ValueError(
                f"channel retry_backoff {self.retry_backoff} must be >= 0"
            )
        if not (self.corrupt_std > 0.0):
            raise ValueError(
                f"channel corrupt_std {self.corrupt_std} must be > 0"
            )


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """The scripted fault world (``ScenarioConfig.faults``).

    Every family is optional; ``FaultConfig()`` (all ``None``) is
    bit-identical to ``faults=None`` — zero extra RNG draws on any stream."""

    drift: Optional[DriftConfig] = None
    crash: Optional[CrashConfig] = None
    outage: Optional[OutageConfig] = None
    wave: Optional[WaveConfig] = None
    byzantine: Optional[ByzantineConfig] = None
    channel: Optional[ChannelConfig] = None

    @property
    def any_active(self) -> bool:
        return any(
            f is not None
            for f in (self.drift, self.crash, self.outage, self.wave,
                      self.byzantine, self.channel)
        )


def fault_ledger(events: Sequence) -> Dict[str, int]:
    """The run-level fault ledger from a round-events sequence.

    One pure function of the (engine-independent) per-round events, used by
    every sync engine — so ``SimResult`` ledgers are identical across
    sequential / masked / fused by construction.  All zeros when no faults
    ran.  ``retry_total`` counts re-join attempts (rounds a recovering
    worker trained without counting toward aggregation) plus channel
    delivery retries; ``byz_commits`` / ``lost_commits`` / ``dup_commits``
    / ``corrupt_commits`` count per-round submission outcomes."""
    led = dict(
        drift_events=0, rounds_degraded=0, rounds_skipped=0,
        workers_recovered=0, retry_total=0,
        byz_commits=0, lost_commits=0, dup_commits=0, corrupt_commits=0,
    )
    for ev in events:
        led["drift_events"] += int(getattr(ev, "drift_changed", False))
        led["rounds_skipped"] += int(getattr(ev, "skip", False))
        led["rounds_degraded"] += int(getattr(ev, "degraded", False))
        rec = getattr(ev, "recovered", None)
        if rec is not None:
            led["workers_recovered"] += int(np.asarray(rec).sum())
        ring = getattr(ev, "recovering", None)
        if ring is not None:
            led["retry_total"] += int(
                (np.asarray(ring) & np.asarray(ev.active)).sum()
            )
        sub = np.asarray(ev.submitters)
        byz = getattr(ev, "byz", None)
        if byz is not None:
            led["byz_commits"] += int((np.asarray(byz) & sub).sum())
        retr = getattr(ev, "retries", None)
        if retr is not None:
            led["retry_total"] += int(np.asarray(retr)[sub].sum())
        delv = getattr(ev, "delivered", None)
        if delv is not None:
            led["lost_commits"] += int((~np.asarray(delv) & sub).sum())
            led["dup_commits"] += int(
                (np.asarray(ev.dup) & np.asarray(delv) & sub).sum()
            )
            led["corrupt_commits"] += int(
                (np.asarray(ev.corrupt) & np.asarray(delv) & sub).sum()
            )
    return led
