"""Global-index (I_w) machinery: sub-model masks, budgeted pruning, nesting.

Terminology follows the paper (Tab. I): worker w's sub-model is identified by
its *global index* ``I_w`` — for each prunable layer, the sorted ids of the
retained units w.r.t. the global base model.  Pruning removes units; the model
is then *reconfigured* (physically smaller arrays), and the global index is
what lets the server embed sub-model parameters back into base-model
coordinates for aggregation.

Units are "interior" structural groups whose parameter cost is independent of
other layers' choices: attention KV-head groups, FFN hidden units, experts,
recurrent channels, conv filters.  Each layer advertises a per-unit parameter
cost so pruned rates are enforced in *parameter space* (the paper's budget is
a fraction of model size).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence

import numpy as np

__all__ = [
    "UnitLayer",
    "UnitSpace",
    "full_index",
    "retention",
    "payload_bytes",
    "prune_to_budget",
    "similarity",
    "is_nested",
    "take_units",
    "embed_units",
]

GlobalIndex = Dict[str, np.ndarray]


@dataclasses.dataclass(frozen=True)
class UnitLayer:
    """One prunable unit dimension of the base model."""

    name: str
    num_units: int
    unit_param_cost: int  # parameters attributable to ONE unit of this layer
    min_units: int = 1    # never prune a layer empty


@dataclasses.dataclass(frozen=True)
class UnitSpace:
    """Inventory of prunable units + the fixed (never-pruned) parameter mass."""

    layers: Sequence[UnitLayer]
    fixed_params: int  # embeddings, norms, protected layers ...

    def layer(self, name: str) -> UnitLayer:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)

    @property
    def unit_counts(self) -> Dict[str, int]:
        return {l.name: l.num_units for l in self.layers}

    @property
    def total_params(self) -> int:
        return self.fixed_params + sum(
            l.num_units * l.unit_param_cost for l in self.layers
        )


def full_index(space: UnitSpace) -> GlobalIndex:
    return {l.name: np.arange(l.num_units) for l in space.layers}


def _retained_params(index: GlobalIndex, space: UnitSpace) -> int:
    return space.fixed_params + sum(
        len(index[l.name]) * l.unit_param_cost for l in space.layers
    )


def retention(index: GlobalIndex, space: UnitSpace) -> float:
    """gamma: retained parameter fraction of the base model."""
    return _retained_params(index, space) / space.total_params


def payload_bytes(index: GlobalIndex, space: UnitSpace, bytes_per_param: int = 4) -> float:
    """Communication payload of the sub-model (params + the index itself).

    The paper notes AdaptCL only adds the global index + pruned rate to the
    per-round message; we count it (4 bytes/unit id) to back the "little
    communication overhead" claim.
    """
    index_bytes = sum(len(v) * 4 for v in index.values()) + 8
    return _retained_params(index, space) * bytes_per_param + index_bytes


def prune_to_budget(
    index: GlobalIndex,
    scores: Mapping[str, np.ndarray],
    pruned_rate: float,
    space: UnitSpace,
) -> GlobalIndex:
    """Cut the lowest-scored retained units until ``pruned_rate`` of the
    *current* model's parameters is removed (global threshold across layers,
    as in CIG-BNscalor: "prune units below a global importance threshold
    across all layers, defined from the pruning budget").

    Scores index into base-model unit ids; protected layers simply do not
    appear in ``space.layers``.
    """
    if not (0.0 <= pruned_rate < 1.0):
        raise ValueError(f"pruned_rate {pruned_rate} outside [0,1)")
    if pruned_rate == 0.0:
        return {k: v.copy() for k, v in index.items()}
    current = _retained_params(index, space)
    budget = pruned_rate * current
    # Gather (score, layer, unit, cost) for every retained unit.
    entries: List[tuple] = []
    for l in space.layers:
        sc = np.asarray(scores[l.name], dtype=np.float64)
        if sc.shape[0] != l.num_units:
            raise ValueError(
                f"scores for {l.name} have {sc.shape[0]} entries, want {l.num_units}"
            )
        for u in index[l.name]:
            entries.append((sc[u], l.name, int(u), l.unit_param_cost))
    # Ascending score = prune first. Tie-break on (layer, unit) for
    # determinism across workers (Identical principle).
    entries.sort(key=lambda e: (e[0], e[1], e[2]))
    removed: Dict[str, set] = {l.name: set() for l in space.layers}
    removed_params = 0
    n_retained = {l.name: len(index[l.name]) for l in space.layers}
    min_units = {l.name: l.min_units for l in space.layers}
    for score, lname, unit, cost in entries:
        if removed_params >= budget:
            break
        if n_retained[lname] <= min_units[lname]:
            continue
        removed[lname].add(unit)
        n_retained[lname] -= 1
        removed_params += cost
    out: GlobalIndex = {}
    for l in space.layers:
        keep = np.array(
            [u for u in index[l.name] if int(u) not in removed[l.name]], dtype=np.int64
        )
        out[l.name] = keep
    return out


def similarity(i1: GlobalIndex, i2: GlobalIndex) -> float:
    """Eq. 3: mean Jaccard similarity of retained units per layer."""
    keys = sorted(set(i1) | set(i2))
    vals = []
    for k in keys:
        a, b = set(map(int, i1.get(k, []))), set(map(int, i2.get(k, [])))
        union = a | b
        if not union:
            continue
        vals.append(len(a & b) / len(union))
    return float(np.mean(vals)) if vals else 1.0


def is_nested(small: GlobalIndex, big: GlobalIndex) -> bool:
    """I_small ⊂ I_big (the Identical+Constant guarantee, §III-D)."""
    for k, v in small.items():
        if not set(map(int, v)) <= set(map(int, big.get(k, []))):
            return False
    return True


# --- array helpers used by reconfigure + aggregation -----------------------

def take_units(arr: np.ndarray, idx: np.ndarray, axis: int) -> np.ndarray:
    """Slice retained units out of a base-coordinate array."""
    return np.take(arr, idx, axis=axis)


def embed_units(
    small: np.ndarray, idx: np.ndarray, axis: int, full_dim: int
) -> np.ndarray:
    """Zero-fill a sub-model array back into base-model coordinates.

    Pruned positions become exactly 0 — the By-worker aggregation semantics.
    """
    shape = list(small.shape)
    shape[axis] = full_dim
    out = np.zeros(shape, dtype=small.dtype)
    indexer: List = [slice(None)] * small.ndim
    indexer[axis] = idx
    out[tuple(indexer)] = small
    return out
