"""Global-index (I_w) machinery: sub-model masks, budgeted pruning, nesting.

Terminology follows the paper (Tab. I): worker w's sub-model is identified by
its *global index* ``I_w`` — for each prunable layer, the sorted ids of the
retained units w.r.t. the global base model.  Pruning removes units; the model
is then *reconfigured* (physically smaller arrays), and the global index is
what lets the server embed sub-model parameters back into base-model
coordinates for aggregation.

Units are "interior" structural groups whose parameter cost is independent of
other layers' choices: attention KV-head groups, FFN hidden units, experts,
recurrent channels, conv filters.  Each layer advertises a per-unit parameter
cost so pruned rates are enforced in *parameter space* (the paper's budget is
a fraction of model size).

**Device pruning** (the fused round engine's path): :class:`UnitFlat`
flattens the unit space into static per-unit arrays (layer id, cost,
tie-break rank), ``prune_order`` reproduces ``prune_to_budget``'s exact host
sort — ascending ``(score, layer_name, unit)`` in float64 — as an integer
permutation, ``prune_budget_units`` converts the float64 budget into the
exact integer threshold the greedy walk compares against, and
``prune_presence_rows`` replays the same greedy removal as a ``lax.scan``
over the order, vmapped across worker rows of a ``[W, U]`` 0/1 presence
matrix.  Because the order is a host-exact permutation and the budget an
exact integer, the device path removes *bit-identical* unit sets to
``prune_to_budget`` (pinned by the golden tie-breaking test).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "UnitLayer",
    "UnitSpace",
    "UnitFlat",
    "full_index",
    "retention",
    "payload_bytes",
    "prune_to_budget",
    "flatten_unit_space",
    "presence_from_index",
    "index_from_presence",
    "prune_order",
    "prune_budget_units",
    "prune_presence_rows",
    "grow_order",
    "regrow_index",
    "regrow_presence_rows",
    "similarity",
    "is_nested",
    "take_units",
    "embed_units",
]

GlobalIndex = Dict[str, np.ndarray]


@dataclasses.dataclass(frozen=True)
class UnitLayer:
    """One prunable unit dimension of the base model."""

    name: str
    num_units: int
    unit_param_cost: int  # parameters attributable to ONE unit of this layer
    min_units: int = 1    # never prune a layer empty


@dataclasses.dataclass(frozen=True)
class UnitSpace:
    """Inventory of prunable units + the fixed (never-pruned) parameter mass."""

    layers: Sequence[UnitLayer]
    fixed_params: int  # embeddings, norms, protected layers ...

    def layer(self, name: str) -> UnitLayer:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)

    @property
    def unit_counts(self) -> Dict[str, int]:
        return {l.name: l.num_units for l in self.layers}

    @property
    def total_params(self) -> int:
        return self.fixed_params + sum(
            l.num_units * l.unit_param_cost for l in self.layers
        )


def full_index(space: UnitSpace) -> GlobalIndex:
    return {l.name: np.arange(l.num_units) for l in space.layers}


def _retained_params(index: GlobalIndex, space: UnitSpace) -> int:
    return space.fixed_params + sum(
        len(index[l.name]) * l.unit_param_cost for l in space.layers
    )


def retention(index: GlobalIndex, space: UnitSpace) -> float:
    """gamma: retained parameter fraction of the base model."""
    return _retained_params(index, space) / space.total_params


def payload_bytes(index: GlobalIndex, space: UnitSpace, bytes_per_param: int = 4) -> float:
    """Communication payload of the sub-model (params + the index itself).

    The paper notes AdaptCL only adds the global index + pruned rate to the
    per-round message; we count it (4 bytes/unit id) to back the "little
    communication overhead" claim.
    """
    index_bytes = sum(len(v) * 4 for v in index.values()) + 8
    return _retained_params(index, space) * bytes_per_param + index_bytes


def prune_to_budget(
    index: GlobalIndex,
    scores: Mapping[str, np.ndarray],
    pruned_rate: float,
    space: UnitSpace,
) -> GlobalIndex:
    """Cut the lowest-scored retained units until ``pruned_rate`` of the
    *current* model's parameters is removed (global threshold across layers,
    as in CIG-BNscalor: "prune units below a global importance threshold
    across all layers, defined from the pruning budget").

    Scores index into base-model unit ids; protected layers simply do not
    appear in ``space.layers``.
    """
    if not (0.0 <= pruned_rate < 1.0):
        raise ValueError(f"pruned_rate {pruned_rate} outside [0,1)")
    if pruned_rate == 0.0:
        return {k: v.copy() for k, v in index.items()}
    current = _retained_params(index, space)
    budget = pruned_rate * current
    # Gather (score, layer, unit, cost) for every retained unit.
    entries: List[tuple] = []
    for l in space.layers:
        sc = np.asarray(scores[l.name], dtype=np.float64)
        if sc.shape[0] != l.num_units:
            raise ValueError(
                f"scores for {l.name} have {sc.shape[0]} entries, want {l.num_units}"
            )
        for u in index[l.name]:
            entries.append((sc[u], l.name, int(u), l.unit_param_cost))
    # Ascending score = prune first. Tie-break on (layer, unit) for
    # determinism across workers (Identical principle).
    entries.sort(key=lambda e: (e[0], e[1], e[2]))
    removed: Dict[str, set] = {l.name: set() for l in space.layers}
    removed_params = 0
    n_retained = {l.name: len(index[l.name]) for l in space.layers}
    min_units = {l.name: l.min_units for l in space.layers}
    for score, lname, unit, cost in entries:
        if removed_params >= budget:
            break
        if n_retained[lname] <= min_units[lname]:
            continue
        removed[lname].add(unit)
        n_retained[lname] -= 1
        removed_params += cost
    out: GlobalIndex = {}
    for l in space.layers:
        keep = np.array(
            [u for u in index[l.name] if int(u) not in removed[l.name]], dtype=np.int64
        )
        out[l.name] = keep
    return out


def similarity(i1: GlobalIndex, i2: GlobalIndex) -> float:
    """Eq. 3: mean Jaccard similarity of retained units per layer."""
    keys = sorted(set(i1) | set(i2))
    vals = []
    for k in keys:
        a, b = set(map(int, i1.get(k, []))), set(map(int, i2.get(k, [])))
        union = a | b
        if not union:
            continue
        vals.append(len(a & b) / len(union))
    return float(np.mean(vals)) if vals else 1.0


def is_nested(small: GlobalIndex, big: GlobalIndex) -> bool:
    """I_small ⊂ I_big (the Identical+Constant guarantee, §III-D)."""
    for k, v in small.items():
        if not set(map(int, v)) <= set(map(int, big.get(k, []))):
            return False
    return True


# --- flattened unit space + device-side budget pruning ---------------------

@dataclasses.dataclass(frozen=True)
class UnitFlat:
    """Static flattening of a :class:`UnitSpace` into per-unit arrays.

    Unit ``j`` of layer ``names[l]`` lives at flat slot ``offsets[l] + j``.
    ``tiebreak[u]`` is the rank of slot ``u`` in the ascending
    ``(layer_name, unit_id)`` order — exactly the tie-break
    ``prune_to_budget`` applies between equal scores."""

    names: tuple                 # layer names, in space.layers order
    sizes: np.ndarray            # [L] units per layer
    offsets: np.ndarray          # [L] flat offset of each layer
    layer_of: np.ndarray         # [U] int32 layer id per flat slot
    unit_id: np.ndarray          # [U] int32 unit id within its layer
    costs: np.ndarray            # [U] int32 per-unit parameter cost
    min_units: np.ndarray        # [L] int32
    fixed_params: int
    tiebreak: np.ndarray         # [U] int32 (layer_name, unit) rank

    @property
    def num_units(self) -> int:
        return int(self.layer_of.shape[0])


def flatten_unit_space(space: UnitSpace) -> UnitFlat:
    names = tuple(l.name for l in space.layers)
    sizes = np.array([l.num_units for l in space.layers], np.int64)
    offsets = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int64)
    layer_of = np.concatenate(
        [np.full(l.num_units, i, np.int32) for i, l in enumerate(space.layers)]
    )
    unit_id = np.concatenate(
        [np.arange(l.num_units, dtype=np.int32) for l in space.layers]
    )
    costs = np.concatenate(
        [np.full(l.num_units, l.unit_param_cost, np.int32) for l in space.layers]
    )
    min_units = np.array([l.min_units for l in space.layers], np.int32)
    # rank in ascending (layer_name, unit) order — the host sort's tie-break
    name_rank = np.argsort(np.argsort(np.array(names)))
    tiebreak = np.lexsort((unit_id, name_rank[layer_of]))
    rank = np.empty_like(tiebreak)
    rank[tiebreak] = np.arange(len(tiebreak))
    return UnitFlat(
        names=names, sizes=sizes, offsets=offsets, layer_of=layer_of,
        unit_id=unit_id, costs=costs, min_units=min_units,
        fixed_params=int(space.fixed_params), tiebreak=rank.astype(np.int32),
    )


def presence_from_index(index: GlobalIndex, flat: UnitFlat) -> np.ndarray:
    """[U] float32 0/1 flat presence vector of a global index."""
    p = np.zeros(flat.num_units, np.float32)
    for l, name in enumerate(flat.names):
        p[flat.offsets[l] + np.asarray(index[name], np.int64)] = 1.0
    return p


def index_from_presence(presence: np.ndarray, flat: UnitFlat) -> GlobalIndex:
    """Inverse of ``presence_from_index`` (retained slots, ascending)."""
    presence = np.asarray(presence)
    out: GlobalIndex = {}
    for l, name in enumerate(flat.names):
        seg = presence[flat.offsets[l] : flat.offsets[l] + flat.sizes[l]]
        out[name] = np.flatnonzero(seg > 0).astype(np.int64)
    return out


def prune_order(scores: Mapping[str, np.ndarray], flat: UnitFlat) -> np.ndarray:
    """[U] removal-order permutation matching ``prune_to_budget``'s sort.

    Host-exact: float64 scores, ties broken by ``(layer_name, unit)`` — the
    same key the per-worker path sorts its ``(score, lname, unit, cost)``
    entries by, so walking this order removes units in the identical
    sequence.  Non-retained slots simply get skipped by the presence guard
    during the walk, which is equivalent to the host path never listing
    them."""
    flat_scores = np.concatenate([
        np.asarray(scores[name], np.float64)[: flat.sizes[l]]
        for l, name in enumerate(flat.names)
    ])
    if flat_scores.shape[0] != flat.num_units:
        raise ValueError("scores do not cover the unit space")
    return np.lexsort((flat.tiebreak, flat_scores)).astype(np.int32)


def prune_budget_units(index: GlobalIndex, rate: float, space: UnitSpace) -> int:
    """Exact integer removal threshold for one worker's prune event.

    ``prune_to_budget`` removes while ``removed_params < rate * current``
    with ``removed_params`` an integer sum of integer unit costs; since
    ``removed < b`` for integer ``removed`` equals ``removed < ceil(b)``
    (``b`` non-integral) or ``removed < b`` (``b`` integral), the float64
    budget collapses to an integer the device greedy can compare exactly —
    no float32 drift can flip a removal decision."""
    budget = float(rate) * _retained_params(index, space)
    ceil_b = int(np.ceil(budget))
    return int(budget) if budget == np.floor(budget) else ceil_b


def prune_presence_rows(
    presence: jnp.ndarray,       # [W, U] float32 0/1
    orders: jnp.ndarray,         # [W, U] int32 removal order per worker
    budgets: jnp.ndarray,        # [W] int32 (prune_budget_units per worker)
    flat: UnitFlat,
) -> jnp.ndarray:
    """Device replay of ``prune_to_budget`` over worker rows (pure ``jnp``).

    A ``lax.scan`` walks each worker's removal order: a slot is removed iff
    the budget is not yet met, its layer stays above ``min_units``, and the
    worker still retains it — the exact greedy of the host loop, including
    the "skipped layers don't consume budget" semantics.  ``budgets == 0``
    rows come back unchanged (the host's ``pruned_rate == 0`` early-out)."""
    layer_of = jnp.asarray(flat.layer_of)
    costs = jnp.asarray(flat.costs)
    min_units = jnp.asarray(flat.min_units)
    L = len(flat.names)

    def one(pres, order, budget):
        counts = jnp.zeros((L,), jnp.int32).at[layer_of].add(pres.astype(jnp.int32))

        def body(carry, u):
            removed, counts, pres = carry
            l = layer_of[u]
            can = (
                (removed < budget)
                & (counts[l] > min_units[l])
                & (pres[u] > 0)
            )
            pres = pres.at[u].add(jnp.where(can, -1.0, 0.0))
            counts = counts.at[l].add(jnp.where(can, -1, 0))
            removed = removed + jnp.where(can, costs[u], 0)
            return (removed, counts, pres), None

        (_, _, pres), _ = jax.lax.scan(
            body, (jnp.int32(0), counts, pres), order
        )
        return pres

    return jax.vmap(one)(presence, orders, budgets)


# --- FedDST-style regrowth: grow orders + host/device greedy ---------------

def grow_order(scores: Mapping[str, np.ndarray], flat: UnitFlat) -> np.ndarray:
    """[U] grow-order permutation: DESCENDING score, same tie-break.

    The mirror image of ``prune_order``: regrowth adds the highest-scored
    absent units first (FedDST grows by gradient magnitude), ties broken by
    the ascending ``(layer_name, unit)`` rank so host and device walk the
    identical sequence.  Implemented as a lexsort over the negated float64
    scores — negation is exact in IEEE, so equal scores stay equal and the
    tie-break still decides."""
    flat_scores = np.concatenate([
        np.asarray(scores[name], np.float64)[: flat.sizes[l]]
        for l, name in enumerate(flat.names)
    ])
    if flat_scores.shape[0] != flat.num_units:
        raise ValueError("scores do not cover the unit space")
    return np.lexsort((flat.tiebreak, -flat_scores)).astype(np.int32)


def regrow_index(
    index: GlobalIndex,
    scores: Mapping[str, np.ndarray],
    budget_params: int,
    space: UnitSpace,
) -> GlobalIndex:
    """Host greedy regrowth: add absent units in descending-score order
    until ``budget_params`` parameters have been re-added.

    The exact mirror of ``prune_to_budget``'s greedy: walk the global grow
    order, add a unit iff the budget is not yet met and the unit is absent.
    ``budget_params`` is an integer (the parameter mass a preceding shrink
    removed), so no float comparison can diverge between host and device."""
    if budget_params <= 0:
        return {k: np.asarray(v, np.int64).copy() for k, v in index.items()}
    flat = flatten_unit_space(space)
    order = grow_order(scores, flat)
    present = presence_from_index(index, flat) > 0
    added = 0
    for u in order:
        if added >= budget_params:
            break
        u = int(u)
        if present[u]:
            continue
        present[u] = True
        added += int(flat.costs[u])
    return index_from_presence(present.astype(np.float32), flat)


def regrow_presence_rows(
    presence: jnp.ndarray,       # [W, U] float32 0/1
    orders: jnp.ndarray,         # [W, U] int32 grow order per worker
    budgets: jnp.ndarray,        # [W] int32 parameter budgets to re-add
    flat: UnitFlat,
) -> jnp.ndarray:
    """Device replay of ``regrow_index`` over worker rows (pure ``jnp``).

    A ``lax.scan`` walks each worker's grow order: a slot is added iff the
    budget is not yet met and the worker does not retain it — the exact host
    greedy.  ``budgets == 0`` rows come back unchanged (workers that did not
    shrink, or the padding rows of a stacked call)."""
    costs = jnp.asarray(flat.costs)

    def one(pres, order, budget):
        def body(carry, u):
            added, pres = carry
            can = (added < budget) & (pres[u] == 0)
            pres = pres.at[u].add(jnp.where(can, 1.0, 0.0))
            added = added + jnp.where(can, costs[u], 0)
            return (added, pres), None

        (_, pres), _ = jax.lax.scan(body, (jnp.int32(0), pres), order)
        return pres

    return jax.vmap(one)(presence, orders, budgets)


# --- array helpers used by reconfigure + aggregation -----------------------

def take_units(arr: np.ndarray, idx: np.ndarray, axis: int) -> np.ndarray:
    """Slice retained units out of a base-coordinate array."""
    return np.take(arr, idx, axis=axis)


def embed_units(
    small: np.ndarray, idx: np.ndarray, axis: int, full_dim: int
) -> np.ndarray:
    """Zero-fill a sub-model array back into base-model coordinates.

    Pruned positions become exactly 0 — the By-worker aggregation semantics.
    """
    shape = list(small.shape)
    shape[axis] = full_dim
    out = np.zeros(shape, dtype=small.dtype)
    indexer: List = [slice(None)] * small.ndim
    indexer[axis] = idx
    out[tuple(indexer)] = small
    return out
