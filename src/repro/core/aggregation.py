"""Model aggregation: By-worker vs By-unit (AdaptCL §III-B, Appendix A Fig. 6).

Workers submit *reconfigured* (physically smaller) parameter arrays together
with their global index I_w.  The server embeds each submission back into
base-model coordinates (pruned positions = 0) and aggregates:

  * **By-worker** (AdaptCL's choice): coefficient 1/W per worker — a pruned
    unit contributes an explicit zero.  Per the lottery-ticket argument [37],
    freezing small weights to zero speeds their optimization to completion.
  * **By-unit**: per-coordinate coefficient 1/w' where w' = number of workers
    that retain the coordinate.  Shown in Fig. 5 to stall accuracy.

Parameters are flat ``{path: array}`` dicts in base coordinates; ``unit_map``
says which prunable unit layer governs which axis of which param:
``unit_map[path] = [(layer_name, axis), ...]`` (a 2-D weight can be governed
on both axes by different unit layers).

Two aggregation representations are supported:

* **per-worker lists** (``aggregate_by_worker`` / ``aggregate_by_unit``):
  reconfigured submissions + indices, embedded one at a time — the
  submission-boundary path;
* **resident stacks** (``aggregate_by_worker_stacked`` /
  ``aggregate_by_unit_stacked``): ``[W, ...]`` base-coordinate param/mask
  stacks consumed directly (masked mean), with a per-worker weight vector —
  the resident fleet engine's path, no per-worker embed calls.

The **async server merges** live here too (:class:`AsyncServer`): polynomial
staleness weighting (fedasync), SSP delta averaging, and DC-ASGD delay
compensation are one per-commit ``commit`` entry point shared by the
per-worker and the resident scheduler paths, so the stacked rewrite cannot
drift from the reference semantics (pinned by the golden staleness tests).
The resident path feeds it rows of the ``[B, ...]`` trained sub-stack pulled
once per fleet call (the "stacked aggregate out"); the per-worker path feeds
it per-worker dicts.  ``async_commit_jnp`` is the pure-``jnp`` twin of
``AsyncServer.commit`` that the fused async engine calls inside its
``lax.scan`` commit walk.

``extract_subparams`` and ``embed_params`` count their invocations in
``ROUNDTRIP_COUNTS`` so the simulator can assert that the resident engine
performs zero host round-trips inside the round loop.  The per-worker async
path additionally tallies one ``async_merge`` per commit (each commit copies
a full per-worker param dict across the host boundary), so
``SimResult.host_roundtrips`` is honest for the baseline the resident
equivalence tests compare against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .masks import GlobalIndex, embed_units

__all__ = [
    "QuarantineConfig",
    "RobustAggConfig",
    "byzantine_transform_jnp",
    "clip_deltas_jnp",
    "corrupt_transform_jnp",
    "delta_norms_jnp",
    "health_step_jnp",
    "async_health_step_jnp",
    "noise_key",
    "robust_aggregate_stacked_jnp",
    "robust_submission_step_jnp",
    "trimmed_mean_stacked_jnp",
    "UnitMap",
    "embed_params",
    "coordinate_mask",
    "extract_subparams",
    "subparam_shapes",
    "aggregate_by_worker",
    "aggregate_by_unit",
    "aggregate_by_worker_stacked",
    "aggregate_by_unit_stacked",
    "aggregate_by_worker_stacked_jnp",
    "aggregate_by_unit_stacked_jnp",
    "dgc_compress_jnp",
    "fedasync_weight",
    "AsyncServer",
    "async_commit_jnp",
    "ROUNDTRIP_COUNTS",
    "roundtrip_total",
    "reset_roundtrip_counts",
    "tally_roundtrip",
]

UnitMap = Mapping[str, Sequence[Tuple[str, int]]]
Params = Dict[str, np.ndarray]

# host round-trip counters (see module docstring): extract/embed crossings in
# the sync loop, per-commit param-dict merges in the per-worker async loop
ROUNDTRIP_COUNTS: Dict[str, int] = {
    "extract_subparams": 0,
    "embed_params": 0,
    "async_merge": 0,
}


def roundtrip_total() -> int:
    return sum(ROUNDTRIP_COUNTS.values())


def reset_roundtrip_counts() -> None:
    for k in ROUNDTRIP_COUNTS:
        ROUNDTRIP_COUNTS[k] = 0


def tally_roundtrip(kind: str, n: int = 1) -> None:
    """Record host round-trips that don't flow through extract/embed (the
    per-worker async path's per-commit param-dict merges)."""
    ROUNDTRIP_COUNTS[kind] = ROUNDTRIP_COUNTS.get(kind, 0) + n


def _full_dims(base_shapes: Mapping[str, tuple], path: str, axis: int) -> int:
    return base_shapes[path][axis]


def extract_subparams(
    global_params: Params, index: GlobalIndex, unit_map: UnitMap
) -> Params:
    """theta_g ⊙ I_w (Alg. 1 server line 9): slice the sub-model out of the
    global model along every governed axis."""
    ROUNDTRIP_COUNTS["extract_subparams"] += 1
    out: Params = {}
    for path, arr in global_params.items():
        for lname, axis in unit_map.get(path, ()):  # successive axis slices
            arr = np.take(arr, index[lname], axis=axis)
        out[path] = arr
    return out


def embed_params(
    sub_params: Params,
    index: GlobalIndex,
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> Params:
    """Zero-fill sub-model params into base coordinates."""
    ROUNDTRIP_COUNTS["embed_params"] += 1
    out: Params = {}
    for path, arr in sub_params.items():
        for lname, axis in unit_map.get(path, ()):
            arr = embed_units(arr, np.asarray(index[lname]), axis, base_shapes[path][axis])
        if arr.shape != tuple(base_shapes[path]):
            raise ValueError(
                f"{path}: embedded {arr.shape} != base {base_shapes[path]}"
            )
        out[path] = arr
    return out


def coordinate_mask(
    path: str,
    index: GlobalIndex,
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> np.ndarray:
    """1.0 where worker retains the coordinate, else 0.0 (broadcastable)."""
    shape = base_shapes[path]
    mask = np.ones(shape, dtype=np.float64)
    for lname, axis in unit_map.get(path, ()):
        axis_mask = np.zeros(shape[axis], dtype=np.float64)
        axis_mask[np.asarray(index[lname], dtype=np.int64)] = 1.0
        bshape = [1] * len(shape)
        bshape[axis] = shape[axis]
        mask = mask * axis_mask.reshape(bshape)
    return mask


def aggregate_by_worker(
    submissions: Sequence[Tuple[Params, GlobalIndex]],
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
    data_weights: Sequence[float] | None = None,
) -> Params:
    """theta_g = sum_w c_w * embed(theta_w); c_w = 1/W (or data-weighted)."""
    W = len(submissions)
    if data_weights is None:
        weights = np.full(W, 1.0 / W)
    else:
        weights = np.asarray(data_weights, dtype=np.float64)
        weights = weights / weights.sum()
    out: Params = {}
    for w, (sub, idx) in enumerate(submissions):
        emb = embed_params(sub, idx, unit_map, base_shapes)
        for path, arr in emb.items():
            acc = out.get(path)
            contrib = weights[w] * arr.astype(np.float64)
            out[path] = contrib if acc is None else acc + contrib
    return {k: v for k, v in out.items()}


def aggregate_by_unit(
    submissions: Sequence[Tuple[Params, GlobalIndex]],
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> Params:
    """Per-coordinate 1/w' averaging over the holders of each coordinate."""
    num: Params = {}
    den: Params = {}
    for sub, idx in submissions:
        emb = embed_params(sub, idx, unit_map, base_shapes)
        for path, arr in emb.items():
            m = coordinate_mask(path, idx, unit_map, base_shapes)
            num[path] = num.get(path, 0.0) + arr.astype(np.float64)
            den[path] = den.get(path, 0.0) + m
    return {p: num[p] / np.maximum(den[p], 1.0) for p in num}


# --- resident-stack representation ----------------------------------------

def subparam_shapes(
    index: GlobalIndex, unit_map: UnitMap, base_shapes: Mapping[str, tuple]
) -> Dict[str, tuple]:
    """Reconfigured array shapes for a sub-model, without materializing it.

    This is what lets the resident engine compute payload bytes / FLOPs for
    the channel model with zero ``extract_subparams`` calls."""
    out: Dict[str, tuple] = {}
    for path, shape in base_shapes.items():
        s = list(shape)
        for lname, axis in unit_map.get(path, ()):
            s[axis] = len(index[lname])
        out[path] = tuple(s)
    return out


def aggregate_by_worker_stacked(
    param_stacks: Mapping[str, np.ndarray],   # {path: [W, ...]} masked stacks
    weights: np.ndarray,                      # [W]; 0 for non-submitters
) -> Params:
    """By-worker aggregation straight off the resident ``[W, ...]`` stacks.

    Rows are already masked (pruned coordinates exactly 0), so the embed step
    of the per-worker path is a no-op here: theta_g = sum_w c_w * stack_w."""
    weights = np.asarray(weights, dtype=np.float64)
    out: Params = {}
    for path, stack in param_stacks.items():
        arr = np.asarray(stack, dtype=np.float64)
        out[path] = np.tensordot(weights, arr, axes=1)
    return out


def aggregate_by_worker_stacked_jnp(
    param_stacks: Mapping[str, jnp.ndarray],   # {path: [W, ...]} masked stacks
    weights: jnp.ndarray,                      # [W]; 0 for non-submitters
    axis: Optional[str] = None,
) -> Dict[str, jnp.ndarray]:
    """Pure-``jnp`` by-worker aggregation — the fused round engine's in-scan
    server step.  Numerics: float32 on device vs the host path's float64
    accumulate-then-cast; the engine-equivalence tests bound the drift.

    ``axis`` turns this into the TWO-TIER hierarchical server of the
    mesh-sharded fleet (edge -> regional -> global parameter servers): under
    ``shard_map`` each device sees only its ``W_local`` rows, the local
    ``tensordot`` is the regional server's partial reduce over its edge
    workers, and the closing ``psum`` over the fleet mesh axis is the global
    tier — sum over shards of per-shard weighted sums, an on-mesh
    all-reduce, never a host gather."""
    out = {
        path: jnp.tensordot(weights, stack, axes=1)
        for path, stack in param_stacks.items()
    }
    if axis is not None:
        out = {path: jax.lax.psum(v, axis) for path, v in out.items()}
    return out


def aggregate_by_unit_stacked_jnp(
    param_stacks: Mapping[str, jnp.ndarray],
    mask_stacks: Mapping[str, jnp.ndarray],
    submitters: jnp.ndarray,                   # [W] float 0/1
    axis: Optional[str] = None,
) -> Dict[str, jnp.ndarray]:
    """Pure-``jnp`` per-coordinate 1/w' masked mean (fused by-unit path).

    Under a fleet mesh axis the numerator AND the holder-count denominator
    each two-tier independently (per-shard partial sums, then one ``psum``
    apiece), and only then divide — dividing per-shard would weight each
    regional mean by its local holders instead of the global w'."""
    out: Dict[str, jnp.ndarray] = {}
    for path, stack in param_stacks.items():
        num = jnp.tensordot(submitters, stack, axes=1)
        den = jnp.tensordot(submitters, mask_stacks[path], axes=1)
        if axis is not None:
            num = jax.lax.psum(num, axis)
            den = jax.lax.psum(den, axis)
        out[path] = num / jnp.maximum(den, 1.0)
    return out


# --- device DGC delta compression (fused submission boundary) --------------

def dgc_compress_jnp(
    deltas: Mapping[str, jnp.ndarray],     # {path: [W, ...]} param - global*mask
    residual: Mapping[str, jnp.ndarray],   # {path: [W, ...]} carried residuals
    sparsity: float,                       # static Python float in (0, 1)
    masks: Optional[Mapping[str, jnp.ndarray]],  # {path: [W, ...]} 0/1, or None
    rows: jnp.ndarray,                     # [W] float 0/1 submitter gate
) -> Tuple[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    """Pure-``jnp`` twin of ``simulation._dgc_compress_stacked`` — the fused
    engine's in-scan top-|.| delta compressor.

    Per key the accumulated delta (``delta + residual``) is flattened to a
    ``[W, N]`` view; pruned coordinates are invalidated to ``-1.0`` so the
    per-row keep budget covers RETAINED coordinates only, the row's
    ``n_keep``-th largest |value| becomes the threshold, and ``>= thr``
    keeps (ties included, exactly like the host).  Committed parts go to the
    output, the rest carries as the new residual; ``rows == 0`` workers
    commit nothing and keep their old residual untouched (which also makes
    dead padding rounds of a fused chunk no-ops).

    Bit-identity with the host compressor: both compute the keep budget with
    the same float32 ops (``round(f32(sizes) * f32(1 - sparsity))``, round
    half to even) and threshold the same float32 values — sorting picks a
    VALUE, not an index, so host ``np.sort`` and device ``jnp.sort`` agree
    bit-for-bit and the keep sets are identical (pinned by the host/device
    golden test).  Returns ``(committed, new_residual, kept, total)`` with
    ``kept``/``total`` the REALIZED per-worker committed-coordinate counts
    (``[W]`` int32) for payload accounting.
    """
    W = rows.shape[0]
    committed: Dict[str, jnp.ndarray] = {}
    new_res: Dict[str, jnp.ndarray] = {}
    kept = jnp.zeros((W,), jnp.int32)
    total = jnp.zeros((W,), jnp.int32)
    rows_b = rows > 0
    keep_frac = jnp.float32(1.0 - sparsity)
    for k, d in deltas.items():
        acc = d + residual[k]
        flat = acc.reshape(W, -1)
        absf = jnp.abs(flat)
        if masks is not None:
            valid = masks[k].reshape(W, -1) > 0
            sizes = valid.sum(axis=1).astype(jnp.int32)
            absf = jnp.where(valid, absf, -1.0)
        else:
            valid = None
            sizes = jnp.full((W,), flat.shape[1], jnp.int32)
        n_keep = jnp.maximum(
            1, jnp.round(sizes.astype(jnp.float32) * keep_frac).astype(jnp.int32)
        )
        n_keep = jnp.minimum(n_keep, jnp.maximum(sizes, 1))
        order = jnp.sort(absf, axis=1)[:, ::-1]
        thr = order[jnp.arange(W), n_keep - 1]
        keep = absf >= thr[:, None]
        if valid is not None:
            keep = keep & valid
        com = jnp.where(keep, flat, 0.0)
        res = jnp.where(keep, 0.0, flat)
        if valid is not None:
            res = jnp.where(valid, res, 0.0)
        old_res = residual[k].reshape(W, -1)
        gate = rows_b[:, None]
        committed[k] = jnp.where(gate, com, 0.0).reshape(d.shape)
        new_res[k] = jnp.where(gate, res, old_res).reshape(d.shape)
        kept = kept + jnp.where(rows_b, keep.sum(axis=1).astype(jnp.int32), 0)
        total = total + jnp.where(rows_b, sizes, 0)
    return committed, new_res, kept, total


# --- robust aggregation layer (clip / trimmed-mean / quarantine) ----------

@dataclasses.dataclass(frozen=True)
class QuarantineConfig:
    """Server-side health tracker: quarantine repeated MAD-outlier workers.

    Each aggregated round the server computes the median and the median
    absolute deviation (MAD) of the eligible submitters' pre-clip update
    norms; a worker whose norm deviates more than ``threshold`` MADs strikes
    (consecutive strikes reset on a clean round).  ``strikes`` consecutive
    strikes quarantine the worker for ``probation`` aggregated rounds — its
    commits are excluded from aggregation exactly like ``recovering`` rows
    (it still trains and its phi still gates the round clock, so virtual
    clocks stay engine-identical) — after which it is readmitted with a
    clean record."""

    threshold: float = 3.0  # MAD multiples before a norm counts as an outlier
    strikes: int = 2        # consecutive outlier rounds before quarantine
    probation: int = 3      # aggregated rounds excluded once quarantined

    def __post_init__(self):
        if not (self.threshold > 0.0):
            raise ValueError(
                f"quarantine threshold {self.threshold} must be > 0"
            )
        if self.strikes < 1:
            raise ValueError(f"quarantine strikes {self.strikes} must be >= 1")
        if self.probation < 1:
            raise ValueError(
                f"quarantine probation {self.probation} must be >= 1"
            )


@dataclasses.dataclass(frozen=True)
class RobustAggConfig:
    """The robust server aggregation layer (``SimConfig.robust``).

    ``clip`` bounds each commit's L2 delta norm (None = no clipping);
    ``trim`` is the per-end coordinate-wise trimmed-mean fraction (0 = plain
    weighted mean — bit-identical to the pre-feature server by a static
    branch); ``quarantine`` enables the MAD-outlier health tracker.
    ``RobustAggConfig()`` (all defaults) is a no-op."""

    clip: Optional[float] = None
    trim: float = 0.0
    quarantine: Optional[QuarantineConfig] = None

    def __post_init__(self):
        if self.clip is not None and not (self.clip > 0.0):
            raise ValueError(f"robust clip {self.clip} must be > 0")
        if not (0.0 <= self.trim < 0.5):
            raise ValueError(f"robust trim {self.trim} outside [0, 0.5)")

    @property
    def any_active(self) -> bool:
        return (
            self.clip is not None
            or self.trim > 0.0
            or self.quarantine is not None
        )


def noise_key(seed: int, round_t: int) -> jnp.ndarray:
    """Per-round noise key for byzantine/corruption payload garbling.

    ``fold_in(PRNGKey(seed), round_t)`` — a pure function of (seed, round),
    so the masked engine (calling per round) and the fused engine (feeding a
    precomputed ``[K, 2]`` key stack into the scan) generate bit-identical
    noise."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), round_t)


def _leaf_noise(key: jnp.ndarray, leaf_idx: int, shape, dtype) -> jnp.ndarray:
    return jax.random.normal(jax.random.fold_in(key, leaf_idx), shape, dtype)


def _row_bcast(row: jnp.ndarray, ndim: int) -> jnp.ndarray:
    return row.reshape((row.shape[0],) + (1,) * (ndim - 1))


def _stack_noise(
    key: jnp.ndarray, leaf_idx: int, leaf: jnp.ndarray,
    full_rows: Optional[int], row_offset,
) -> jnp.ndarray:
    """[W_local, ...] noise rows for ``leaf``, drawn at FULL fleet width.

    Under a fleet mesh each shard generates the full ``[W, ...]`` noise
    stack and slices its own row block, so mesh and no-mesh runs (and every
    mesh size) see bit-identical noise per global slot."""
    if full_rows is None or full_rows == leaf.shape[0]:
        noise = _leaf_noise(key, leaf_idx, leaf.shape, leaf.dtype)
        if row_offset is None:
            return noise
        return jax.lax.dynamic_slice_in_dim(noise, row_offset, leaf.shape[0], 0)
    full = _leaf_noise(
        key, leaf_idx, (full_rows,) + leaf.shape[1:], leaf.dtype
    )
    off = 0 if row_offset is None else row_offset
    return jax.lax.dynamic_slice_in_dim(full, off, leaf.shape[0], 0)


def byzantine_transform_jnp(
    deltas: Mapping[str, jnp.ndarray],      # {path: [W, ...]} committed deltas
    masks: Optional[Mapping[str, jnp.ndarray]],  # {path: [W, ...]} 0/1, or None
    byz_row: jnp.ndarray,                   # [W] bool: compromised this round
    *,
    mode: str,
    scale: float,
    noise_std: float,
    key: jnp.ndarray,
    full_rows: Optional[int] = None,
    row_offset=None,
) -> Dict[str, jnp.ndarray]:
    """Apply the Byzantine attack to compromised rows of a delta stack.

    A pure transform at the submission boundary: honest rows pass through
    bit-untouched; attacked rows are masked back to their live coordinates
    (an attacker cannot write into coordinates it does not hold)."""
    out: Dict[str, jnp.ndarray] = {}
    for i, k in enumerate(sorted(deltas)):
        d = deltas[k]
        if mode == "sign_flip":
            atk = -d
        elif mode == "scale":
            atk = jnp.asarray(scale, d.dtype) * d
        else:  # "noise"
            noise = _stack_noise(key, i, d, full_rows, row_offset)
            atk = d + jnp.asarray(noise_std, d.dtype) * noise
        if masks is not None:
            atk = atk * masks[k]
        out[k] = jnp.where(_row_bcast(byz_row, d.ndim), atk, d)
    return out


def corrupt_transform_jnp(
    deltas: Mapping[str, jnp.ndarray],
    masks: Optional[Mapping[str, jnp.ndarray]],
    corrupt_row: jnp.ndarray,               # [W] bool: payload garbled
    *,
    corrupt_std: float,
    key: jnp.ndarray,
    full_rows: Optional[int] = None,
    row_offset=None,
) -> Dict[str, jnp.ndarray]:
    """Garble corrupted rows of a delta stack: ``delta + corrupt_std * N``.

    The lossy channel's payload corruption — same shape discipline as
    :func:`byzantine_transform_jnp` (leaf index 1000+i folds the corruption
    stream away from the attack stream, so a round with both families does
    not reuse noise)."""
    out: Dict[str, jnp.ndarray] = {}
    for i, k in enumerate(sorted(deltas)):
        d = deltas[k]
        noise = _stack_noise(key, 1000 + i, d, full_rows, row_offset)
        bad = d + jnp.asarray(corrupt_std, d.dtype) * noise
        if masks is not None:
            bad = bad * masks[k]
        out[k] = jnp.where(_row_bcast(corrupt_row, d.ndim), bad, d)
    return out


def delta_norms_jnp(deltas: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
    """Per-worker L2 norm of a delta stack across all leaves — ``[W]`` f32.

    Leaves reduce in sorted-key order so the masked loop and the fused scan
    accumulate in the same order (bit-identical norms)."""
    total = None
    for k in sorted(deltas):
        d = deltas[k].astype(jnp.float32)
        sq = jnp.sum(d.reshape(d.shape[0], -1) ** 2, axis=1)
        total = sq if total is None else total + sq
    return jnp.sqrt(total)


def clip_deltas_jnp(
    deltas: Mapping[str, jnp.ndarray],
    norms: jnp.ndarray,                     # [W] f32 pre-clip norms
    clip: float,
) -> Dict[str, jnp.ndarray]:
    """Per-commit L2 norm clipping: rows with ``norm > clip`` are scaled to
    the clip sphere; rows at or under the bound pass through bit-untouched
    (the scale multiplies by exactly 1.0)."""
    scale = jnp.minimum(
        jnp.float32(1.0), jnp.float32(clip) / jnp.maximum(norms, 1e-30)
    )
    return {
        k: d * _row_bcast(scale.astype(d.dtype), d.ndim)
        for k, d in deltas.items()
    }


def health_step_jnp(
    norms: jnp.ndarray,      # [W] f32: this round's update norms
    eligible: jnp.ndarray,   # [W] bool: submitted AND delivered this round
    strikes: jnp.ndarray,    # [W] int32 carry
    quar_left: jnp.ndarray,  # [W] int32 carry: probation rounds remaining
    *,
    threshold: float,
    strikes_needed: int,
    probation: int,
    gate=None,               # scalar bool: False = dead round, state untouched
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One aggregated round of the MAD-outlier health tracker.

    Returns ``(quar_now, strikes', quar_left')`` where ``quar_now`` marks
    rows quarantined at round START (excluded from this round's
    aggregation).  Median/MAD are lower medians over the eligible,
    non-quarantined rows; the MAD floor ``max(MAD, 1e-6 |median| + 1e-12)``
    keeps an all-identical honest cohort from flagging on f32 dust.  The
    same function runs in the masked loop, inside the fused scan (``gate``
    rides the chunk's ``real`` mask so dead padding rounds leave the carry
    untouched), and — on gathered full-fleet norms — under the mesh."""
    quar_now = quar_left > 0
    elig = eligible & ~quar_now
    n = elig.sum()
    x = jnp.where(elig, norms, jnp.inf)
    med = jnp.sort(x)[jnp.maximum(n - 1, 0) // 2]
    dev = jnp.where(elig, jnp.abs(norms - med), jnp.inf)
    mad = jnp.sort(dev)[jnp.maximum(n - 1, 0) // 2]
    floor = jnp.maximum(mad, 1e-6 * jnp.abs(med) + 1e-12)
    outlier = elig & (jnp.abs(norms - med) > jnp.float32(threshold) * floor)
    outlier = outlier & (n > 0)
    strikes2 = jnp.where(
        elig, jnp.where(outlier, strikes + 1, 0), strikes
    ).astype(strikes.dtype)
    enter = elig & (strikes2 >= strikes_needed)
    quar2 = jnp.where(
        enter, probation, jnp.maximum(quar_left - 1, 0)
    ).astype(quar_left.dtype)
    strikes2 = jnp.where(enter, 0, strikes2).astype(strikes.dtype)
    if gate is not None:
        strikes2 = jnp.where(gate, strikes2, strikes)
        quar2 = jnp.where(gate, quar2, quar_left)
    return quar_now, strikes2, quar2


def async_health_step_jnp(
    norm: jnp.ndarray,        # scalar f32: this commit's update norm
    worker: jnp.ndarray,      # scalar int32 slot id
    strikes: jnp.ndarray,     # [W] int32 carry
    quar_left: jnp.ndarray,   # [W] int32 carry
    last_norms: jnp.ndarray,  # [W] f32 carry: last commit norm per slot
    seen: jnp.ndarray,        # [W] bool carry: slot has committed before
    *,
    threshold: float,
    strikes_needed: int,
    probation: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-commit health step for the async schedulers.

    The population for the median/MAD is each slot's LAST commit norm (there
    is no synchronized cohort in an event queue).  Returns ``(reject,
    strikes', quar_left', last_norms', seen')`` — ``reject`` is True for a
    commit from a quarantined slot (its probation counts down per rejected
    commit) or the commit that triggers quarantine; rejected commits still
    bump the server version (the event plan's version trajectory is fixed at
    plan time), their parameters are simply discarded."""
    norms2 = last_norms.at[worker].set(norm)
    seen2 = seen.at[worker].set(True)
    quar_now = quar_left[worker] > 0
    n = seen2.sum()
    x = jnp.where(seen2, norms2, jnp.inf)
    med = jnp.sort(x)[jnp.maximum(n - 1, 0) // 2]
    dev = jnp.where(seen2, jnp.abs(norms2 - med), jnp.inf)
    mad = jnp.sort(dev)[jnp.maximum(n - 1, 0) // 2]
    floor = jnp.maximum(mad, 1e-6 * jnp.abs(med) + 1e-12)
    outlier = jnp.abs(norm - med) > jnp.float32(threshold) * floor
    s_w = jnp.where(outlier & ~quar_now, strikes[worker] + 1, 0)
    enter = ~quar_now & (s_w >= strikes_needed)
    strikes2 = strikes.at[worker].set(
        jnp.where(enter, 0, s_w).astype(strikes.dtype)
    )
    quar2 = quar_left.at[worker].set(
        jnp.where(
            enter, probation, jnp.maximum(quar_left[worker] - 1, 0)
        ).astype(quar_left.dtype)
    )
    return quar_now | enter, strikes2, quar2, norms2, seen2


def trimmed_mean_stacked_jnp(
    param_stacks: Mapping[str, jnp.ndarray],   # {path: [W, ...]} masked stacks
    mask_stacks: Optional[Mapping[str, jnp.ndarray]],  # presence, or None
    eligible: jnp.ndarray,                     # [W] bool: votes counted
    trim: float,                               # static per-end trim fraction
) -> Dict[str, jnp.ndarray]:
    """Coordinate-wise trimmed mean over a resident stack, presence-aware.

    Per coordinate, only HOLDER votes (eligible rows whose presence mask
    retains the coordinate) enter the order statistics — structural zeros
    from pruned rows cannot crowd the trim window.  ``k = floor(trim * n_c)``
    votes are dropped from each end of the ``n_c`` holder votes, the
    surviving votes are averaged, and the result is rescaled by
    ``n_c / |eligible|`` — the zero-vote shrinkage of by-worker averaging —
    so a fault-free trimmed mean matches the plain mean's scale on partially
    held coordinates."""
    elig_n = jnp.maximum(eligible.sum().astype(jnp.float32), 1.0)
    W = eligible.shape[0]
    ranks = jnp.arange(W)
    out: Dict[str, jnp.ndarray] = {}
    for k in sorted(param_stacks):
        stack = param_stacks[k].astype(jnp.float32)
        valid = _row_bcast(eligible, stack.ndim)
        if mask_stacks is not None:
            valid = valid & (mask_stacks[k] > 0)
        else:
            valid = jnp.broadcast_to(valid, stack.shape)
        n_c = valid.sum(axis=0)
        k_c = jnp.floor(jnp.float32(trim) * n_c.astype(jnp.float32)).astype(n_c.dtype)
        xs = jnp.sort(jnp.where(valid, stack, jnp.inf), axis=0)
        r = ranks.reshape((W,) + (1,) * (stack.ndim - 1))
        keep = (r >= k_c) & (r < n_c - k_c)
        kept_sum = jnp.where(keep, xs, 0.0).sum(axis=0)
        keep_n = jnp.maximum(n_c - 2 * k_c, 1).astype(jnp.float32)
        est = kept_sum * n_c.astype(jnp.float32) / (keep_n * elig_n)
        out[k] = jnp.where(n_c > 0, est, 0.0)
    return out


def robust_aggregate_stacked_jnp(
    param_stacks: Mapping[str, jnp.ndarray],   # {path: [W, ...]} masked stacks
    weights: jnp.ndarray,                      # [W] f32 multiplicity weights
    mask_stacks: Optional[Mapping[str, jnp.ndarray]] = None,
    *,
    trim: float = 0.0,
    axis: Optional[str] = None,
) -> Dict[str, jnp.ndarray]:
    """The robust server's aggregation step.

    ``trim == 0`` routes to :func:`aggregate_by_worker_stacked_jnp`
    LITERALLY (a static Python branch) — trim-free robust aggregation is
    bit-identical to the plain server, not merely close.  ``trim > 0`` runs
    the presence-aware coordinate-wise trimmed mean; relative multiplicity
    is deliberately ignored there (a duplicated delivery is one vote —
    trimmed-mean deduplicates by construction), only ``weights > 0``
    eligibility counts.

    Under a fleet mesh axis the trimmed path ALL-GATHERS the shards' row
    blocks (``sharding.collectives.all_gather_fleet``) — cross-shard order
    statistics need every vote — and computes the full-fleet trim
    replicated per shard; the degenerate 1-device mesh gathers a block of
    everything, bit-identical to no-mesh."""
    if trim == 0.0:
        return aggregate_by_worker_stacked_jnp(param_stacks, weights, axis)
    eligible = weights > 0
    if axis is not None:
        from ..sharding.collectives import all_gather_fleet  # lazy: no cycle

        param_stacks = all_gather_fleet(dict(param_stacks), axis)
        if mask_stacks is not None:
            mask_stacks = all_gather_fleet(dict(mask_stacks), axis)
        eligible = all_gather_fleet(eligible, axis)
    return trimmed_mean_stacked_jnp(param_stacks, mask_stacks, eligible, trim)


def robust_submission_step_jnp(
    param_stacks: Mapping[str, jnp.ndarray],   # {path: [Wl, ...]} committed rows
    mask_stacks: Optional[Mapping[str, jnp.ndarray]],
    global_p: Mapping[str, jnp.ndarray],       # {path: [...]} current global
    mult: jnp.ndarray,                         # [Wl] f32 multiplicity weights
    weights: jnp.ndarray,                      # [Wl] f32 normalized weights
    byz_row: Optional[jnp.ndarray],            # [Wl] bool, or None
    corrupt_row: Optional[jnp.ndarray],        # [Wl] bool, or None
    byz_key: Optional[jnp.ndarray],
    corrupt_key: Optional[jnp.ndarray],
    strikes: Optional[jnp.ndarray],            # [W] int32 full-fleet carry
    quar_left: Optional[jnp.ndarray],          # [W] int32 full-fleet carry
    *,
    byz_mode: str = "sign_flip",
    byz_scale: float = -10.0,
    byz_noise_std: float = 1.0,
    corrupt_std: float = 10.0,
    clip: Optional[float] = None,
    trim: float = 0.0,
    quarantine: Optional[QuarantineConfig] = None,
    gate=None,
    axis: Optional[str] = None,
    full_rows: Optional[int] = None,
) -> Tuple[
    Dict[str, jnp.ndarray],
    Optional[jnp.ndarray], Optional[jnp.ndarray], Optional[jnp.ndarray],
]:
    """One submission-boundary server round: attack -> defense -> aggregate.

    THE shared twin: the masked loop calls it per round on host-fed stacks,
    the fused engine calls it inside the ``lax.scan`` chunk body, and under a
    fleet mesh it runs per shard on ``[W_local, ...]`` row blocks
    (``full_rows`` = fleet W) — same function, so robust worlds keep the
    engine-equivalence guarantees by construction.  Order matters and is
    fixed: byzantine transform, channel corruption, pre-clip norms, health
    quarantine, norm clip, aggregate (plain weighted mean or trimmed mean).

    ``mult`` is the channel multiplicity vector (submit * delivered *
    (1 + dup), f32) and drives eligibility everywhere; ``weights`` is the
    pre-normalized plain-mean vector used when no quarantine reweights
    in-scan.  Returns ``(new_global_f32, strikes', quar_left', quar_now)`` —
    the health carries pass through untouched when ``quarantine`` is None.
    A round with zero delivered weight keeps the global unchanged."""
    stacks = {k: v.astype(jnp.float32) for k, v in param_stacks.items()}
    masks = mask_stacks
    w_local = mult.shape[0]
    row_offset = None
    if axis is not None:
        row_offset = jax.lax.axis_index(axis) * w_local
    norms = None
    if (byz_row is not None or corrupt_row is not None
            or clip is not None or quarantine is not None):
        if masks is not None:
            bcast = {k: global_p[k][None] * masks[k] for k in stacks}
        else:
            bcast = {
                k: jnp.broadcast_to(global_p[k][None], stacks[k].shape)
                for k in stacks
            }
        deltas = {k: stacks[k] - bcast[k] for k in stacks}
        if byz_row is not None:
            deltas = byzantine_transform_jnp(
                deltas, masks, byz_row, mode=byz_mode, scale=byz_scale,
                noise_std=byz_noise_std, key=byz_key,
                full_rows=full_rows, row_offset=row_offset,
            )
        if corrupt_row is not None:
            deltas = corrupt_transform_jnp(
                deltas, masks, corrupt_row, corrupt_std=corrupt_std,
                key=corrupt_key, full_rows=full_rows, row_offset=row_offset,
            )
        if clip is not None or quarantine is not None:
            norms = delta_norms_jnp(deltas)
        if clip is not None:
            deltas = clip_deltas_jnp(deltas, norms, clip)
        stacks = {k: bcast[k] + deltas[k] for k in stacks}
    quar_now = None
    strikes2, quar2 = strikes, quar_left
    if quarantine is not None:
        if axis is not None:
            from ..sharding.collectives import (  # lazy: no import cycle
                all_gather_fleet, shard_row_slice,
            )

            norms_full = all_gather_fleet(norms, axis)
            mult_full = all_gather_fleet(mult, axis)
        else:
            norms_full, mult_full = norms, mult
        quar_now, strikes2, quar2 = health_step_jnp(
            norms_full, mult_full > 0, strikes, quar_left,
            threshold=quarantine.threshold,
            strikes_needed=quarantine.strikes,
            probation=quarantine.probation, gate=gate,
        )
        w_full = mult_full * (1.0 - quar_now.astype(jnp.float32))
        wsum = w_full.sum()
        if trim > 0.0:
            if axis is not None:
                stacks_g = all_gather_fleet(stacks, axis)
                masks_g = (
                    all_gather_fleet(dict(masks), axis)
                    if masks is not None else None
                )
            else:
                stacks_g, masks_g = stacks, masks
            agg = trimmed_mean_stacked_jnp(stacks_g, masks_g, w_full > 0, trim)
        else:
            weights_full = w_full / jnp.maximum(wsum, jnp.float32(1e-30))
            w_loc = (
                shard_row_slice(weights_full, w_local, axis)
                if axis is not None else weights_full
            )
            agg = aggregate_by_worker_stacked_jnp(stacks, w_loc, axis)
    else:
        wsum = mult.sum()
        if axis is not None:
            wsum = jax.lax.psum(wsum, axis)
        if trim > 0.0:
            agg = robust_aggregate_stacked_jnp(
                stacks, mult, masks, trim=trim, axis=axis
            )
        else:
            agg = aggregate_by_worker_stacked_jnp(stacks, weights, axis)
    new = {
        k: jnp.where(wsum > 0, agg[k], global_p[k].astype(jnp.float32))
        for k in stacks
    }
    return new, strikes2, quar2, quar_now


# --- async server merges (fedasync_s / ssp_s / dcasgd_s) -------------------

def fedasync_weight(a0: float, staleness: float) -> float:
    """Xie et al. polynomial staleness weighting: ``a0 * (s + 1)^-0.5``."""
    return float(a0 * (staleness + 1.0) ** -0.5)


class AsyncServer:
    """Per-commit server state for the asynchronous schedulers.

    One ``commit`` entry point implements all three merge rules in base
    coordinates, so the per-worker and resident scheduler paths share the
    exact same staleness-weighting math:

    * ``fedasync_s`` — ``theta <- (1-a) theta + a theta_w`` with the
      polynomial staleness weight ``a = fedasync_weight(a0, s)``;
    * ``ssp_s``      — ``theta <- theta + (theta_w - fetched_w) / N`` where
      ``N`` is the *committing cohort* size (``cohort_size``, defaulting to
      the slot pool ``num_workers``): under async client sampling only C*W
      workers ever commit, and SSP's delta averaging is over them;
    * ``dcasgd_s``   — DC-ASGD-a: the committed "gradient" is the accumulated
      local update divided by lr, compensated by ``lam_t * g^2 * (theta -
      w_bak)`` with a mean-square-adaptive ``lam_t``.

    DC-ASGD bookkeeping is *stacked*: ``backup`` is a ``{path: [W, ...]}``
    base-coordinate array over the full slot pool (worker w's ``w_bak`` is
    row w — slot ids index it even when only a cohort commits) and ``dc_m``
    the running mean-square accumulator, so the resident path never
    materializes per-worker dicts for it.  ``commit`` always rebinds
    ``self.params`` to a fresh dict (never mutates arrays in place), which
    is what lets callers keep zero-copy references to fetched snapshots.
    """

    def __init__(
        self,
        method: str,
        global_params: Params,
        num_workers: int,
        *,
        cohort_size: Optional[int] = None,
        fedasync_a: float = 0.5,
        lr: float = 0.05,
        dcasgd_lambda: float = 2.0,
        dcasgd_m: float = 0.95,
        clip_norm: Optional[float] = None,
        quarantine: Optional[QuarantineConfig] = None,
    ):
        self.method = method
        self.params: Params = dict(global_params)
        self.num_workers = num_workers
        self.cohort_size = num_workers if cohort_size is None else cohort_size
        self.version = 0
        self.fedasync_a = fedasync_a
        self.lr = lr
        self.dcasgd_lambda = dcasgd_lambda
        self.dcasgd_m = dcasgd_m
        self.backup: Optional[Dict[str, np.ndarray]] = None
        self.dc_m: Optional[Params] = None
        if method == "dcasgd_s":
            self.backup = {
                k: np.repeat(np.asarray(v)[None], num_workers, axis=0)
                for k, v in global_params.items()
            }
            self.dc_m = {k: np.zeros_like(v) for k, v in global_params.items()}
        # robust layer: per-commit norm clip + MAD-outlier quarantine (the
        # health math is float32, mirroring the fused engine's in-scan twin)
        self.clip_norm = clip_norm
        self.quarantine = quarantine
        self.strikes = np.zeros(num_workers, dtype=np.int32)
        self.quar_left = np.zeros(num_workers, dtype=np.int32)
        self.last_norms = np.zeros(num_workers, dtype=np.float32)
        self.seen = np.zeros(num_workers, dtype=bool)
        self.rejected_commits = 0

    @staticmethod
    def _delta_norm(delta: Params) -> np.float32:
        """f32 mirror of ``delta_norms_jnp`` for one worker's delta dict."""
        tot = np.float32(0.0)
        for k in sorted(delta):
            d = np.asarray(delta[k], np.float32).ravel()
            tot = np.float32(tot + np.float32(np.sum(d * d, dtype=np.float32)))
        return np.float32(np.sqrt(tot))

    def _health_step(self, norm: np.float32, worker: int) -> bool:
        """Host twin of ``async_health_step_jnp`` — returns reject."""
        q = self.quarantine
        self.last_norms[worker] = norm
        self.seen[worker] = True
        quar_now = bool(self.quar_left[worker] > 0)
        n = int(self.seen.sum())
        x = np.where(self.seen, self.last_norms, np.inf).astype(np.float32)
        med = np.sort(x)[max(n - 1, 0) // 2]
        dev = np.where(
            self.seen, np.abs(self.last_norms - med), np.inf
        ).astype(np.float32)
        mad = np.sort(dev)[max(n - 1, 0) // 2]
        floor = np.maximum(mad, np.float32(1e-6 * abs(med) + 1e-12))
        outlier = bool(abs(norm - med) > np.float32(q.threshold) * floor)
        s_w = self.strikes[worker] + 1 if (outlier and not quar_now) else 0
        enter = (not quar_now) and s_w >= q.strikes
        self.strikes[worker] = 0 if enter else s_w
        self.quar_left[worker] = (
            q.probation if enter else max(self.quar_left[worker] - 1, 0)
        )
        return quar_now or enter

    def commit(
        self, worker: int, trained: Params, fetched: Params, staleness: int
    ) -> Params:
        """Apply one worker's commit; returns (and rebinds) the new global."""
        if self.clip_norm is not None or self.quarantine is not None:
            delta = {
                k: np.asarray(trained[k], np.float64) - np.asarray(fetched[k], np.float64)
                for k in trained
            }
            norm = self._delta_norm(delta)
            if self.quarantine is not None and self._health_step(norm, worker):
                # rejected: the update is discarded but the version still
                # bumps — the pre-simulated event plan's staleness/version
                # trajectory is fixed at plan time
                self.rejected_commits += 1
                self.version += 1
                return self.params
            if self.clip_norm is not None:
                scale = float(np.minimum(
                    np.float32(1.0),
                    np.float32(self.clip_norm) / np.maximum(norm, np.float32(1e-30)),
                ))
                trained = {
                    k: np.asarray(fetched[k], np.float64) + delta[k] * scale
                    for k in trained
                }
        g = self.params
        if self.method == "fedasync_s":
            a = fedasync_weight(self.fedasync_a, staleness)
            new = {k: (1 - a) * g[k] + a * trained[k] for k in g}
        elif self.method == "ssp_s":
            new = {
                k: g[k] + (trained[k] - fetched[k]) / self.cohort_size for k in g
            }
        elif self.method == "dcasgd_s":
            new = {}
            for k in g:
                grad = (fetched[k] - trained[k]) / self.lr
                self.dc_m[k] = (
                    self.dcasgd_m * self.dc_m[k]
                    + (1 - self.dcasgd_m) * grad * grad
                )
                lam_t = self.dcasgd_lambda / np.sqrt(np.mean(self.dc_m[k]) + 1e-12)
                comp = grad + lam_t * grad * grad * (g[k] - self.backup[k][worker])
                new[k] = g[k] - self.lr * comp
            for k in new:
                self.backup[k][worker] = new[k]
        else:
            raise ValueError(f"unknown async method {self.method!r}")
        self.params = new
        self.version += 1
        return new


def async_commit_jnp(
    method: str,
    g: Dict[str, jnp.ndarray],          # global params {path: [...]}
    trained: Dict[str, jnp.ndarray],    # committing worker's trained params
    fetched_w: Dict[str, jnp.ndarray],  # the global it fetched before training
    staleness: jnp.ndarray,             # scalar (int or float)
    worker: jnp.ndarray,                # scalar int32 slot id (traced OK)
    backup: Dict[str, jnp.ndarray],     # dcasgd {path: [W, ...]} ({} otherwise)
    dc_m: Dict[str, jnp.ndarray],       # dcasgd accumulator ({} otherwise)
    *,
    cohort_size: int,
    fedasync_a: float,
    lr: float,
    dcasgd_lambda: float,
    dcasgd_m: float,
    clip_norm: Optional[float] = None,
) -> Tuple[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray], Dict[str, jnp.ndarray]]:
    """Pure-``jnp`` :meth:`AsyncServer.commit` — the fused async engine's
    in-scan server step.  ``method`` is Python-static (one branch traces);
    ``staleness``/``worker`` are traced scalars.  UNGATED: it always computes
    the merge — the caller masks dropped/padding commits with ``jnp.where``
    on the returned state.  Numerics: float32 on device vs the host server's
    float64 accumulate; the engine-equivalence tests bound the drift.

    ``clip_norm`` (static) bounds the commit's local progress: the delta
    ``trained - fetched_w`` is L2-clipped before the method merge — the
    async half of the robust aggregation layer (``clip_norm=None`` traces
    the pre-feature program unchanged)."""
    if clip_norm is not None:
        delta = {k: trained[k] - fetched_w[k] for k in trained}
        norm = delta_norms_jnp(
            {k: d[None] for k, d in delta.items()}
        )[0]
        scale = jnp.minimum(
            jnp.float32(1.0),
            jnp.float32(clip_norm) / jnp.maximum(norm, 1e-30),
        )
        trained = {
            k: fetched_w[k] + delta[k] * scale.astype(delta[k].dtype)
            for k in trained
        }
    if method == "fedasync_s":
        a = fedasync_a * (staleness.astype(jnp.float32) + 1.0) ** -0.5
        new = {k: (1 - a) * g[k] + a * trained[k] for k in g}
        return new, backup, dc_m
    if method == "ssp_s":
        new = {
            k: g[k] + (trained[k] - fetched_w[k]) / cohort_size for k in g
        }
        return new, backup, dc_m
    if method == "dcasgd_s":
        new = {}
        dc_m2 = {}
        backup2 = {}
        for k in g:
            grad = (fetched_w[k] - trained[k]) / lr
            dc_m2[k] = dcasgd_m * dc_m[k] + (1 - dcasgd_m) * grad * grad
            lam_t = dcasgd_lambda / jnp.sqrt(jnp.mean(dc_m2[k]) + 1e-12)
            comp = grad + lam_t * grad * grad * (g[k] - backup[k][worker])
            new[k] = g[k] - lr * comp
        for k in new:
            backup2[k] = backup[k].at[worker].set(new[k])
        return new, backup2, dc_m2
    raise ValueError(f"unknown async method {method!r}")


def aggregate_by_unit_stacked(
    param_stacks: Mapping[str, np.ndarray],   # {path: [W, ...]} masked stacks
    mask_stacks: Mapping[str, np.ndarray],    # {path: [W, ...]} 0/1 stacks
    submitters: np.ndarray,                   # [W] 0/1
) -> Params:
    """Per-coordinate 1/w' masked mean over the submitting rows of the stack."""
    sub = np.asarray(submitters, dtype=np.float64)
    out: Params = {}
    for path, stack in param_stacks.items():
        num = np.tensordot(sub, np.asarray(stack, np.float64), axes=1)
        den = np.tensordot(sub, np.asarray(mask_stacks[path], np.float64), axes=1)
        out[path] = num / np.maximum(den, 1.0)
    return out
