"""Model aggregation: By-worker vs By-unit (AdaptCL §III-B, Appendix A Fig. 6).

Workers submit *reconfigured* (physically smaller) parameter arrays together
with their global index I_w.  The server embeds each submission back into
base-model coordinates (pruned positions = 0) and aggregates:

  * **By-worker** (AdaptCL's choice): coefficient 1/W per worker — a pruned
    unit contributes an explicit zero.  Per the lottery-ticket argument [37],
    freezing small weights to zero speeds their optimization to completion.
  * **By-unit**: per-coordinate coefficient 1/w' where w' = number of workers
    that retain the coordinate.  Shown in Fig. 5 to stall accuracy.

Parameters are flat ``{path: array}`` dicts in base coordinates; ``unit_map``
says which prunable unit layer governs which axis of which param:
``unit_map[path] = [(layer_name, axis), ...]`` (a 2-D weight can be governed
on both axes by different unit layers).

Two aggregation representations are supported:

* **per-worker lists** (``aggregate_by_worker`` / ``aggregate_by_unit``):
  reconfigured submissions + indices, embedded one at a time — the
  submission-boundary path;
* **resident stacks** (``aggregate_by_worker_stacked`` /
  ``aggregate_by_unit_stacked``): ``[W, ...]`` base-coordinate param/mask
  stacks consumed directly (masked mean), with a per-worker weight vector —
  the resident fleet engine's path, no per-worker embed calls.

The **async server merges** live here too (:class:`AsyncServer`): polynomial
staleness weighting (fedasync), SSP delta averaging, and DC-ASGD delay
compensation are one per-commit ``commit`` entry point shared by the
per-worker and the resident scheduler paths, so the stacked rewrite cannot
drift from the reference semantics (pinned by the golden staleness tests).
The resident path feeds it rows of the ``[B, ...]`` trained sub-stack pulled
once per fleet call (the "stacked aggregate out"); the per-worker path feeds
it per-worker dicts.  ``async_commit_jnp`` is the pure-``jnp`` twin of
``AsyncServer.commit`` that the fused async engine calls inside its
``lax.scan`` commit walk.

``extract_subparams`` and ``embed_params`` count their invocations in
``ROUNDTRIP_COUNTS`` so the simulator can assert that the resident engine
performs zero host round-trips inside the round loop.  The per-worker async
path additionally tallies one ``async_merge`` per commit (each commit copies
a full per-worker param dict across the host boundary), so
``SimResult.host_roundtrips`` is honest for the baseline the resident
equivalence tests compare against.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .masks import GlobalIndex, embed_units

__all__ = [
    "UnitMap",
    "embed_params",
    "coordinate_mask",
    "extract_subparams",
    "subparam_shapes",
    "aggregate_by_worker",
    "aggregate_by_unit",
    "aggregate_by_worker_stacked",
    "aggregate_by_unit_stacked",
    "aggregate_by_worker_stacked_jnp",
    "aggregate_by_unit_stacked_jnp",
    "dgc_compress_jnp",
    "fedasync_weight",
    "AsyncServer",
    "async_commit_jnp",
    "ROUNDTRIP_COUNTS",
    "roundtrip_total",
    "reset_roundtrip_counts",
    "tally_roundtrip",
]

UnitMap = Mapping[str, Sequence[Tuple[str, int]]]
Params = Dict[str, np.ndarray]

# host round-trip counters (see module docstring): extract/embed crossings in
# the sync loop, per-commit param-dict merges in the per-worker async loop
ROUNDTRIP_COUNTS: Dict[str, int] = {
    "extract_subparams": 0,
    "embed_params": 0,
    "async_merge": 0,
}


def roundtrip_total() -> int:
    return sum(ROUNDTRIP_COUNTS.values())


def reset_roundtrip_counts() -> None:
    for k in ROUNDTRIP_COUNTS:
        ROUNDTRIP_COUNTS[k] = 0


def tally_roundtrip(kind: str, n: int = 1) -> None:
    """Record host round-trips that don't flow through extract/embed (the
    per-worker async path's per-commit param-dict merges)."""
    ROUNDTRIP_COUNTS[kind] = ROUNDTRIP_COUNTS.get(kind, 0) + n


def _full_dims(base_shapes: Mapping[str, tuple], path: str, axis: int) -> int:
    return base_shapes[path][axis]


def extract_subparams(
    global_params: Params, index: GlobalIndex, unit_map: UnitMap
) -> Params:
    """theta_g ⊙ I_w (Alg. 1 server line 9): slice the sub-model out of the
    global model along every governed axis."""
    ROUNDTRIP_COUNTS["extract_subparams"] += 1
    out: Params = {}
    for path, arr in global_params.items():
        for lname, axis in unit_map.get(path, ()):  # successive axis slices
            arr = np.take(arr, index[lname], axis=axis)
        out[path] = arr
    return out


def embed_params(
    sub_params: Params,
    index: GlobalIndex,
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> Params:
    """Zero-fill sub-model params into base coordinates."""
    ROUNDTRIP_COUNTS["embed_params"] += 1
    out: Params = {}
    for path, arr in sub_params.items():
        for lname, axis in unit_map.get(path, ()):
            arr = embed_units(arr, np.asarray(index[lname]), axis, base_shapes[path][axis])
        if arr.shape != tuple(base_shapes[path]):
            raise ValueError(
                f"{path}: embedded {arr.shape} != base {base_shapes[path]}"
            )
        out[path] = arr
    return out


def coordinate_mask(
    path: str,
    index: GlobalIndex,
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> np.ndarray:
    """1.0 where worker retains the coordinate, else 0.0 (broadcastable)."""
    shape = base_shapes[path]
    mask = np.ones(shape, dtype=np.float64)
    for lname, axis in unit_map.get(path, ()):
        axis_mask = np.zeros(shape[axis], dtype=np.float64)
        axis_mask[np.asarray(index[lname], dtype=np.int64)] = 1.0
        bshape = [1] * len(shape)
        bshape[axis] = shape[axis]
        mask = mask * axis_mask.reshape(bshape)
    return mask


def aggregate_by_worker(
    submissions: Sequence[Tuple[Params, GlobalIndex]],
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
    data_weights: Sequence[float] | None = None,
) -> Params:
    """theta_g = sum_w c_w * embed(theta_w); c_w = 1/W (or data-weighted)."""
    W = len(submissions)
    if data_weights is None:
        weights = np.full(W, 1.0 / W)
    else:
        weights = np.asarray(data_weights, dtype=np.float64)
        weights = weights / weights.sum()
    out: Params = {}
    for w, (sub, idx) in enumerate(submissions):
        emb = embed_params(sub, idx, unit_map, base_shapes)
        for path, arr in emb.items():
            acc = out.get(path)
            contrib = weights[w] * arr.astype(np.float64)
            out[path] = contrib if acc is None else acc + contrib
    return {k: v for k, v in out.items()}


def aggregate_by_unit(
    submissions: Sequence[Tuple[Params, GlobalIndex]],
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> Params:
    """Per-coordinate 1/w' averaging over the holders of each coordinate."""
    num: Params = {}
    den: Params = {}
    for sub, idx in submissions:
        emb = embed_params(sub, idx, unit_map, base_shapes)
        for path, arr in emb.items():
            m = coordinate_mask(path, idx, unit_map, base_shapes)
            num[path] = num.get(path, 0.0) + arr.astype(np.float64)
            den[path] = den.get(path, 0.0) + m
    return {p: num[p] / np.maximum(den[p], 1.0) for p in num}


# --- resident-stack representation ----------------------------------------

def subparam_shapes(
    index: GlobalIndex, unit_map: UnitMap, base_shapes: Mapping[str, tuple]
) -> Dict[str, tuple]:
    """Reconfigured array shapes for a sub-model, without materializing it.

    This is what lets the resident engine compute payload bytes / FLOPs for
    the channel model with zero ``extract_subparams`` calls."""
    out: Dict[str, tuple] = {}
    for path, shape in base_shapes.items():
        s = list(shape)
        for lname, axis in unit_map.get(path, ()):
            s[axis] = len(index[lname])
        out[path] = tuple(s)
    return out


def aggregate_by_worker_stacked(
    param_stacks: Mapping[str, np.ndarray],   # {path: [W, ...]} masked stacks
    weights: np.ndarray,                      # [W]; 0 for non-submitters
) -> Params:
    """By-worker aggregation straight off the resident ``[W, ...]`` stacks.

    Rows are already masked (pruned coordinates exactly 0), so the embed step
    of the per-worker path is a no-op here: theta_g = sum_w c_w * stack_w."""
    weights = np.asarray(weights, dtype=np.float64)
    out: Params = {}
    for path, stack in param_stacks.items():
        arr = np.asarray(stack, dtype=np.float64)
        out[path] = np.tensordot(weights, arr, axes=1)
    return out


def aggregate_by_worker_stacked_jnp(
    param_stacks: Mapping[str, jnp.ndarray],   # {path: [W, ...]} masked stacks
    weights: jnp.ndarray,                      # [W]; 0 for non-submitters
    axis: Optional[str] = None,
) -> Dict[str, jnp.ndarray]:
    """Pure-``jnp`` by-worker aggregation — the fused round engine's in-scan
    server step.  Numerics: float32 on device vs the host path's float64
    accumulate-then-cast; the engine-equivalence tests bound the drift.

    ``axis`` turns this into the TWO-TIER hierarchical server of the
    mesh-sharded fleet (edge -> regional -> global parameter servers): under
    ``shard_map`` each device sees only its ``W_local`` rows, the local
    ``tensordot`` is the regional server's partial reduce over its edge
    workers, and the closing ``psum`` over the fleet mesh axis is the global
    tier — sum over shards of per-shard weighted sums, an on-mesh
    all-reduce, never a host gather."""
    out = {
        path: jnp.tensordot(weights, stack, axes=1)
        for path, stack in param_stacks.items()
    }
    if axis is not None:
        out = {path: jax.lax.psum(v, axis) for path, v in out.items()}
    return out


def aggregate_by_unit_stacked_jnp(
    param_stacks: Mapping[str, jnp.ndarray],
    mask_stacks: Mapping[str, jnp.ndarray],
    submitters: jnp.ndarray,                   # [W] float 0/1
    axis: Optional[str] = None,
) -> Dict[str, jnp.ndarray]:
    """Pure-``jnp`` per-coordinate 1/w' masked mean (fused by-unit path).

    Under a fleet mesh axis the numerator AND the holder-count denominator
    each two-tier independently (per-shard partial sums, then one ``psum``
    apiece), and only then divide — dividing per-shard would weight each
    regional mean by its local holders instead of the global w'."""
    out: Dict[str, jnp.ndarray] = {}
    for path, stack in param_stacks.items():
        num = jnp.tensordot(submitters, stack, axes=1)
        den = jnp.tensordot(submitters, mask_stacks[path], axes=1)
        if axis is not None:
            num = jax.lax.psum(num, axis)
            den = jax.lax.psum(den, axis)
        out[path] = num / jnp.maximum(den, 1.0)
    return out


# --- device DGC delta compression (fused submission boundary) --------------

def dgc_compress_jnp(
    deltas: Mapping[str, jnp.ndarray],     # {path: [W, ...]} param - global*mask
    residual: Mapping[str, jnp.ndarray],   # {path: [W, ...]} carried residuals
    sparsity: float,                       # static Python float in (0, 1)
    masks: Optional[Mapping[str, jnp.ndarray]],  # {path: [W, ...]} 0/1, or None
    rows: jnp.ndarray,                     # [W] float 0/1 submitter gate
) -> Tuple[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray], jnp.ndarray, jnp.ndarray]:
    """Pure-``jnp`` twin of ``simulation._dgc_compress_stacked`` — the fused
    engine's in-scan top-|.| delta compressor.

    Per key the accumulated delta (``delta + residual``) is flattened to a
    ``[W, N]`` view; pruned coordinates are invalidated to ``-1.0`` so the
    per-row keep budget covers RETAINED coordinates only, the row's
    ``n_keep``-th largest |value| becomes the threshold, and ``>= thr``
    keeps (ties included, exactly like the host).  Committed parts go to the
    output, the rest carries as the new residual; ``rows == 0`` workers
    commit nothing and keep their old residual untouched (which also makes
    dead padding rounds of a fused chunk no-ops).

    Bit-identity with the host compressor: both compute the keep budget with
    the same float32 ops (``round(f32(sizes) * f32(1 - sparsity))``, round
    half to even) and threshold the same float32 values — sorting picks a
    VALUE, not an index, so host ``np.sort`` and device ``jnp.sort`` agree
    bit-for-bit and the keep sets are identical (pinned by the host/device
    golden test).  Returns ``(committed, new_residual, kept, total)`` with
    ``kept``/``total`` the REALIZED per-worker committed-coordinate counts
    (``[W]`` int32) for payload accounting.
    """
    W = rows.shape[0]
    committed: Dict[str, jnp.ndarray] = {}
    new_res: Dict[str, jnp.ndarray] = {}
    kept = jnp.zeros((W,), jnp.int32)
    total = jnp.zeros((W,), jnp.int32)
    rows_b = rows > 0
    keep_frac = jnp.float32(1.0 - sparsity)
    for k, d in deltas.items():
        acc = d + residual[k]
        flat = acc.reshape(W, -1)
        absf = jnp.abs(flat)
        if masks is not None:
            valid = masks[k].reshape(W, -1) > 0
            sizes = valid.sum(axis=1).astype(jnp.int32)
            absf = jnp.where(valid, absf, -1.0)
        else:
            valid = None
            sizes = jnp.full((W,), flat.shape[1], jnp.int32)
        n_keep = jnp.maximum(
            1, jnp.round(sizes.astype(jnp.float32) * keep_frac).astype(jnp.int32)
        )
        n_keep = jnp.minimum(n_keep, jnp.maximum(sizes, 1))
        order = jnp.sort(absf, axis=1)[:, ::-1]
        thr = order[jnp.arange(W), n_keep - 1]
        keep = absf >= thr[:, None]
        if valid is not None:
            keep = keep & valid
        com = jnp.where(keep, flat, 0.0)
        res = jnp.where(keep, 0.0, flat)
        if valid is not None:
            res = jnp.where(valid, res, 0.0)
        old_res = residual[k].reshape(W, -1)
        gate = rows_b[:, None]
        committed[k] = jnp.where(gate, com, 0.0).reshape(d.shape)
        new_res[k] = jnp.where(gate, res, old_res).reshape(d.shape)
        kept = kept + jnp.where(rows_b, keep.sum(axis=1).astype(jnp.int32), 0)
        total = total + jnp.where(rows_b, sizes, 0)
    return committed, new_res, kept, total


# --- async server merges (fedasync_s / ssp_s / dcasgd_s) -------------------

def fedasync_weight(a0: float, staleness: float) -> float:
    """Xie et al. polynomial staleness weighting: ``a0 * (s + 1)^-0.5``."""
    return float(a0 * (staleness + 1.0) ** -0.5)


class AsyncServer:
    """Per-commit server state for the asynchronous schedulers.

    One ``commit`` entry point implements all three merge rules in base
    coordinates, so the per-worker and resident scheduler paths share the
    exact same staleness-weighting math:

    * ``fedasync_s`` — ``theta <- (1-a) theta + a theta_w`` with the
      polynomial staleness weight ``a = fedasync_weight(a0, s)``;
    * ``ssp_s``      — ``theta <- theta + (theta_w - fetched_w) / N`` where
      ``N`` is the *committing cohort* size (``cohort_size``, defaulting to
      the slot pool ``num_workers``): under async client sampling only C*W
      workers ever commit, and SSP's delta averaging is over them;
    * ``dcasgd_s``   — DC-ASGD-a: the committed "gradient" is the accumulated
      local update divided by lr, compensated by ``lam_t * g^2 * (theta -
      w_bak)`` with a mean-square-adaptive ``lam_t``.

    DC-ASGD bookkeeping is *stacked*: ``backup`` is a ``{path: [W, ...]}``
    base-coordinate array over the full slot pool (worker w's ``w_bak`` is
    row w — slot ids index it even when only a cohort commits) and ``dc_m``
    the running mean-square accumulator, so the resident path never
    materializes per-worker dicts for it.  ``commit`` always rebinds
    ``self.params`` to a fresh dict (never mutates arrays in place), which
    is what lets callers keep zero-copy references to fetched snapshots.
    """

    def __init__(
        self,
        method: str,
        global_params: Params,
        num_workers: int,
        *,
        cohort_size: Optional[int] = None,
        fedasync_a: float = 0.5,
        lr: float = 0.05,
        dcasgd_lambda: float = 2.0,
        dcasgd_m: float = 0.95,
    ):
        self.method = method
        self.params: Params = dict(global_params)
        self.num_workers = num_workers
        self.cohort_size = num_workers if cohort_size is None else cohort_size
        self.version = 0
        self.fedasync_a = fedasync_a
        self.lr = lr
        self.dcasgd_lambda = dcasgd_lambda
        self.dcasgd_m = dcasgd_m
        self.backup: Optional[Dict[str, np.ndarray]] = None
        self.dc_m: Optional[Params] = None
        if method == "dcasgd_s":
            self.backup = {
                k: np.repeat(np.asarray(v)[None], num_workers, axis=0)
                for k, v in global_params.items()
            }
            self.dc_m = {k: np.zeros_like(v) for k, v in global_params.items()}

    def commit(
        self, worker: int, trained: Params, fetched: Params, staleness: int
    ) -> Params:
        """Apply one worker's commit; returns (and rebinds) the new global."""
        g = self.params
        if self.method == "fedasync_s":
            a = fedasync_weight(self.fedasync_a, staleness)
            new = {k: (1 - a) * g[k] + a * trained[k] for k in g}
        elif self.method == "ssp_s":
            new = {
                k: g[k] + (trained[k] - fetched[k]) / self.cohort_size for k in g
            }
        elif self.method == "dcasgd_s":
            new = {}
            for k in g:
                grad = (fetched[k] - trained[k]) / self.lr
                self.dc_m[k] = (
                    self.dcasgd_m * self.dc_m[k]
                    + (1 - self.dcasgd_m) * grad * grad
                )
                lam_t = self.dcasgd_lambda / np.sqrt(np.mean(self.dc_m[k]) + 1e-12)
                comp = grad + lam_t * grad * grad * (g[k] - self.backup[k][worker])
                new[k] = g[k] - self.lr * comp
            for k in new:
                self.backup[k][worker] = new[k]
        else:
            raise ValueError(f"unknown async method {self.method!r}")
        self.params = new
        self.version += 1
        return new


def async_commit_jnp(
    method: str,
    g: Dict[str, jnp.ndarray],          # global params {path: [...]}
    trained: Dict[str, jnp.ndarray],    # committing worker's trained params
    fetched_w: Dict[str, jnp.ndarray],  # the global it fetched before training
    staleness: jnp.ndarray,             # scalar (int or float)
    worker: jnp.ndarray,                # scalar int32 slot id (traced OK)
    backup: Dict[str, jnp.ndarray],     # dcasgd {path: [W, ...]} ({} otherwise)
    dc_m: Dict[str, jnp.ndarray],       # dcasgd accumulator ({} otherwise)
    *,
    cohort_size: int,
    fedasync_a: float,
    lr: float,
    dcasgd_lambda: float,
    dcasgd_m: float,
) -> Tuple[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray], Dict[str, jnp.ndarray]]:
    """Pure-``jnp`` :meth:`AsyncServer.commit` — the fused async engine's
    in-scan server step.  ``method`` is Python-static (one branch traces);
    ``staleness``/``worker`` are traced scalars.  UNGATED: it always computes
    the merge — the caller masks dropped/padding commits with ``jnp.where``
    on the returned state.  Numerics: float32 on device vs the host server's
    float64 accumulate; the engine-equivalence tests bound the drift."""
    if method == "fedasync_s":
        a = fedasync_a * (staleness.astype(jnp.float32) + 1.0) ** -0.5
        new = {k: (1 - a) * g[k] + a * trained[k] for k in g}
        return new, backup, dc_m
    if method == "ssp_s":
        new = {
            k: g[k] + (trained[k] - fetched_w[k]) / cohort_size for k in g
        }
        return new, backup, dc_m
    if method == "dcasgd_s":
        new = {}
        dc_m2 = {}
        backup2 = {}
        for k in g:
            grad = (fetched_w[k] - trained[k]) / lr
            dc_m2[k] = dcasgd_m * dc_m[k] + (1 - dcasgd_m) * grad * grad
            lam_t = dcasgd_lambda / jnp.sqrt(jnp.mean(dc_m2[k]) + 1e-12)
            comp = grad + lam_t * grad * grad * (g[k] - backup[k][worker])
            new[k] = g[k] - lr * comp
        for k in new:
            backup2[k] = backup[k].at[worker].set(new[k])
        return new, backup2, dc_m2
    raise ValueError(f"unknown async method {method!r}")


def aggregate_by_unit_stacked(
    param_stacks: Mapping[str, np.ndarray],   # {path: [W, ...]} masked stacks
    mask_stacks: Mapping[str, np.ndarray],    # {path: [W, ...]} 0/1 stacks
    submitters: np.ndarray,                   # [W] 0/1
) -> Params:
    """Per-coordinate 1/w' masked mean over the submitting rows of the stack."""
    sub = np.asarray(submitters, dtype=np.float64)
    out: Params = {}
    for path, stack in param_stacks.items():
        num = np.tensordot(sub, np.asarray(stack, np.float64), axes=1)
        den = np.tensordot(sub, np.asarray(mask_stacks[path], np.float64), axes=1)
        out[path] = num / np.maximum(den, 1.0)
    return out
