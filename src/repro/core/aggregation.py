"""Model aggregation: By-worker vs By-unit (AdaptCL §III-B, Appendix A Fig. 6).

Workers submit *reconfigured* (physically smaller) parameter arrays together
with their global index I_w.  The server embeds each submission back into
base-model coordinates (pruned positions = 0) and aggregates:

  * **By-worker** (AdaptCL's choice): coefficient 1/W per worker — a pruned
    unit contributes an explicit zero.  Per the lottery-ticket argument [37],
    freezing small weights to zero speeds their optimization to completion.
  * **By-unit**: per-coordinate coefficient 1/w' where w' = number of workers
    that retain the coordinate.  Shown in Fig. 5 to stall accuracy.

Parameters are flat ``{path: array}`` dicts in base coordinates; ``unit_map``
says which prunable unit layer governs which axis of which param:
``unit_map[path] = [(layer_name, axis), ...]`` (a 2-D weight can be governed
on both axes by different unit layers).

Two aggregation representations are supported:

* **per-worker lists** (``aggregate_by_worker`` / ``aggregate_by_unit``):
  reconfigured submissions + indices, embedded one at a time — the
  submission-boundary path;
* **resident stacks** (``aggregate_by_worker_stacked`` /
  ``aggregate_by_unit_stacked``): ``[W, ...]`` base-coordinate param/mask
  stacks consumed directly (masked mean), with a per-worker weight vector —
  the resident fleet engine's path, no per-worker embed calls.

``extract_subparams`` and ``embed_params`` count their invocations in
``ROUNDTRIP_COUNTS`` so the simulator can assert that the resident engine
performs zero host round-trips inside the round loop.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

from .masks import GlobalIndex, embed_units

__all__ = [
    "UnitMap",
    "embed_params",
    "coordinate_mask",
    "extract_subparams",
    "subparam_shapes",
    "aggregate_by_worker",
    "aggregate_by_unit",
    "aggregate_by_worker_stacked",
    "aggregate_by_unit_stacked",
    "ROUNDTRIP_COUNTS",
    "roundtrip_total",
    "reset_roundtrip_counts",
]

UnitMap = Mapping[str, Sequence[Tuple[str, int]]]
Params = Dict[str, np.ndarray]

# host extract/embed round-trip counters (see module docstring)
ROUNDTRIP_COUNTS: Dict[str, int] = {"extract_subparams": 0, "embed_params": 0}


def roundtrip_total() -> int:
    return sum(ROUNDTRIP_COUNTS.values())


def reset_roundtrip_counts() -> None:
    for k in ROUNDTRIP_COUNTS:
        ROUNDTRIP_COUNTS[k] = 0


def _full_dims(base_shapes: Mapping[str, tuple], path: str, axis: int) -> int:
    return base_shapes[path][axis]


def extract_subparams(
    global_params: Params, index: GlobalIndex, unit_map: UnitMap
) -> Params:
    """theta_g ⊙ I_w (Alg. 1 server line 9): slice the sub-model out of the
    global model along every governed axis."""
    ROUNDTRIP_COUNTS["extract_subparams"] += 1
    out: Params = {}
    for path, arr in global_params.items():
        for lname, axis in unit_map.get(path, ()):  # successive axis slices
            arr = np.take(arr, index[lname], axis=axis)
        out[path] = arr
    return out


def embed_params(
    sub_params: Params,
    index: GlobalIndex,
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> Params:
    """Zero-fill sub-model params into base coordinates."""
    ROUNDTRIP_COUNTS["embed_params"] += 1
    out: Params = {}
    for path, arr in sub_params.items():
        for lname, axis in unit_map.get(path, ()):
            arr = embed_units(arr, np.asarray(index[lname]), axis, base_shapes[path][axis])
        if arr.shape != tuple(base_shapes[path]):
            raise ValueError(
                f"{path}: embedded {arr.shape} != base {base_shapes[path]}"
            )
        out[path] = arr
    return out


def coordinate_mask(
    path: str,
    index: GlobalIndex,
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> np.ndarray:
    """1.0 where worker retains the coordinate, else 0.0 (broadcastable)."""
    shape = base_shapes[path]
    mask = np.ones(shape, dtype=np.float64)
    for lname, axis in unit_map.get(path, ()):
        axis_mask = np.zeros(shape[axis], dtype=np.float64)
        axis_mask[np.asarray(index[lname], dtype=np.int64)] = 1.0
        bshape = [1] * len(shape)
        bshape[axis] = shape[axis]
        mask = mask * axis_mask.reshape(bshape)
    return mask


def aggregate_by_worker(
    submissions: Sequence[Tuple[Params, GlobalIndex]],
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
    data_weights: Sequence[float] | None = None,
) -> Params:
    """theta_g = sum_w c_w * embed(theta_w); c_w = 1/W (or data-weighted)."""
    W = len(submissions)
    if data_weights is None:
        weights = np.full(W, 1.0 / W)
    else:
        weights = np.asarray(data_weights, dtype=np.float64)
        weights = weights / weights.sum()
    out: Params = {}
    for w, (sub, idx) in enumerate(submissions):
        emb = embed_params(sub, idx, unit_map, base_shapes)
        for path, arr in emb.items():
            acc = out.get(path)
            contrib = weights[w] * arr.astype(np.float64)
            out[path] = contrib if acc is None else acc + contrib
    return {k: v for k, v in out.items()}


def aggregate_by_unit(
    submissions: Sequence[Tuple[Params, GlobalIndex]],
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> Params:
    """Per-coordinate 1/w' averaging over the holders of each coordinate."""
    num: Params = {}
    den: Params = {}
    for sub, idx in submissions:
        emb = embed_params(sub, idx, unit_map, base_shapes)
        for path, arr in emb.items():
            m = coordinate_mask(path, idx, unit_map, base_shapes)
            num[path] = num.get(path, 0.0) + arr.astype(np.float64)
            den[path] = den.get(path, 0.0) + m
    return {p: num[p] / np.maximum(den[p], 1.0) for p in num}


# --- resident-stack representation ----------------------------------------

def subparam_shapes(
    index: GlobalIndex, unit_map: UnitMap, base_shapes: Mapping[str, tuple]
) -> Dict[str, tuple]:
    """Reconfigured array shapes for a sub-model, without materializing it.

    This is what lets the resident engine compute payload bytes / FLOPs for
    the channel model with zero ``extract_subparams`` calls."""
    out: Dict[str, tuple] = {}
    for path, shape in base_shapes.items():
        s = list(shape)
        for lname, axis in unit_map.get(path, ()):
            s[axis] = len(index[lname])
        out[path] = tuple(s)
    return out


def aggregate_by_worker_stacked(
    param_stacks: Mapping[str, np.ndarray],   # {path: [W, ...]} masked stacks
    weights: np.ndarray,                      # [W]; 0 for non-submitters
) -> Params:
    """By-worker aggregation straight off the resident ``[W, ...]`` stacks.

    Rows are already masked (pruned coordinates exactly 0), so the embed step
    of the per-worker path is a no-op here: theta_g = sum_w c_w * stack_w."""
    weights = np.asarray(weights, dtype=np.float64)
    out: Params = {}
    for path, stack in param_stacks.items():
        arr = np.asarray(stack, dtype=np.float64)
        out[path] = np.tensordot(weights, arr, axes=1)
    return out


def aggregate_by_unit_stacked(
    param_stacks: Mapping[str, np.ndarray],   # {path: [W, ...]} masked stacks
    mask_stacks: Mapping[str, np.ndarray],    # {path: [W, ...]} 0/1 stacks
    submitters: np.ndarray,                   # [W] 0/1
) -> Params:
    """Per-coordinate 1/w' masked mean over the submitting rows of the stack."""
    sub = np.asarray(submitters, dtype=np.float64)
    out: Params = {}
    for path, stack in param_stacks.items():
        num = np.tensordot(sub, np.asarray(stack, np.float64), axes=1)
        den = np.tensordot(sub, np.asarray(mask_stacks[path], np.float64), axes=1)
        out[path] = num / np.maximum(den, 1.0)
    return out
