"""Multi-worker collaborative-learning simulator (AdaptCL §IV).

Faithful-reproduction engine: W workers with heterogeneous bandwidths (Eq. 6/7
channel model), a virtual clock, and six frameworks:

  * ``adaptcl``    — Algorithm 1 (+ Algorithm 2 pruned-rate learning)
  * ``fedavg``     — McMahan et al. BSP
  * ``fedavg_s``   — + group-lasso sparse training (the paper's main baseline)
  * ``fedasync_s`` — Xie et al. async with polynomial staleness weighting
  * ``ssp_s``      — stale-synchronous parallel (threshold s)
  * ``dcasgd_s``   — DC-ASGD-a (delay-compensated async gradients)

All methods share the same bandwidth assignment, data partition, and model
init, as in the paper.  Update times are simulated through the channel model
(training-time sensitivity to pruning is configurable, Appendix E); virtual
time is what produces the paper's Time columns.

Local training is dispatched through the **fleet engine** (``core.fleet``),
selected by ``SimConfig.engine``:

  * ``"sequential"`` — one scan-train call per worker (reference engine);
  * ``"bucketed"``   — workers sharing a parameter-shape signature are
    stacked and trained in one jitted ``vmap`` call;
  * ``"masked"``     — the **resident** engine: stacked ``[W, ...]``
    base-shape param/mask/momentum arrays live on device across rounds
    (``core.fleet.FleetState``), sub-model identity is carried only by the
    0/1 mask stack, and the synchronous round loop performs ZERO
    ``extract_subparams``/``embed_params`` host round-trips — broadcast-back
    is a masked scatter, training is one vmapped program over the whole
    stack, and aggregation consumes the stacks directly
    (``aggregation.aggregate_by_worker_stacked``).  Extraction happens only
    at the submission/reporting boundary (``SimResult``, data-dependent
    importance scores).  Host cost per round is therefore ~flat in W, which
    is what makes hundreds-of-worker fleets simulable.

Minibatch plans are pre-drawn per worker in a fixed order, so all three
engines consume identical batch sequences and produce numerically equivalent
trained models (``tests/test_fleet_equivalence.py``).

**Scenarios** (``SimConfig.scenario``, ``core.scenario``): per-round client
sampling (fraction C), straggler dropout (timeout semantics), and churn
(slot replacement with fresh shards) apply to the synchronous methods as a
per-round participation mask over the fixed worker slots — under the
resident engine, device shapes never change, so flaky fleets keep the
one-compile guarantee.

The async schedulers' discrete-event timeline is INDEPENDENT of trained
parameter values (async workers never prune, so channel times depend only on
bandwidths + jitter, and SSP blocking only on commit counts).  The entire
run is therefore pre-simulated on host by ``_plan_async_events`` into a
``scenario.AsyncEventPlan`` — commit order (including ``(time, worker)``
finish-tie breaking), staleness integers, dropout outcomes, refetch sets,
window batches and virtual clocks — and every engine replays that ONE plan:

  * the per-worker and resident (``masked``) engines batch event commits
    that land within one virtual window (``SimConfig.async_window``, default
    0 = fully serial) into a single fleet call.  Resident: each window batch
    scatters the committing workers' refetched globals into their
    ``[W, ...]`` rows (masked scatter in), trains the batch as one
    bucket-sized sub-stack program, pulls the trained rows to host in ONE
    copy (stacked aggregate out), and applies the per-commit staleness
    merges (``aggregation.AsyncServer``) in finish order — no
    ``extract_subparams``/``embed_params`` anywhere, so
    ``SimResult.host_roundtrips == 0`` for resident async runs too;
  * the ``fused`` engine (``core.fused.run_async_fused``) moves the event
    loop itself onto the device: the pending-commit queue pop is a device
    ``lexsort`` over sorted finish-time keys, worker clocks / staleness
    counters / the fetched-snapshot stacks are device arrays, and whole
    CHUNKS of window batches — refetch scatter, vmapped training, in-scan
    ``AsyncServer``-equivalent merges — run as one ``lax.scan`` program, so
    ``host_dispatches`` is O(events / round_fusion) instead of O(events).

Async methods honour scenario *client sampling* (a static C-fraction of the
slot pool joins the event loop, ``ScenarioEngine.static_participants``) and
*dropout* (each commit independently times out at the server with
probability ``dropout``: it still trains, counts and refetches, but its
update is discarded — no merge, no version bump, no communicated bytes);
churn and scripted schedules stay sync-only.  Device compute is sized to
the participants.

``SimResult`` reports ``recompiles`` (jit shape-signatures compiled),
``batched_calls`` (device programs launched by the batched engines),
``walltime_s`` (host wall-clock), ``host_roundtrips`` (extract/embed calls
plus per-worker async merge copies inside the loop — 0 for the resident
engine), and ``bucket_sizes`` (the sub-stack row buckets launched, which
bound the recompile count) so the engines' host cost can be compared
directly.
"""
from __future__ import annotations

import dataclasses
import heapq
import time as _time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import (
    SyntheticImageTask,
    batch_iterator,
    partition_dirichlet,
    partition_noniid,
)
from repro.models.cnn import (
    CNNConfig,
    build_unit_space,
    cnn_apply,
    cnn_block_compute,
    cnn_flops,
    cnn_flops_from_shapes,
    extract_bn_scales,
    init_cnn,
    vgg_config,
)

from .aggregation import (
    AsyncServer,
    RobustAggConfig,
    aggregate_by_unit,
    aggregate_by_unit_stacked,
    aggregate_by_worker,
    aggregate_by_worker_stacked,
    coordinate_mask,
    embed_params,
    extract_subparams,
    noise_key,
    robust_submission_step_jnp,
    roundtrip_total,
    subparam_shapes,
    tally_roundtrip,
)
from .fleet import FleetEngine, FleetJob
from .importance import (
    CIG_METHODS,
    METHODS,
    ImportanceContext,
    grad_magnitude_scores,
)
from .masks import (
    full_index,
    is_nested,
    payload_bytes,
    prune_to_budget,
    regrow_index,
    retention,
    similarity,
)
from .faults import fault_ledger
from .pruned_rate import PrunedRateConfig, WorkerHistory, learn_pruned_rates
from .scenario import (
    AsyncEventPlan,
    ScenarioConfig,
    ScenarioEngine,
    full_participation,
)
from .timing import HeterogeneityConfig, heterogeneity_from_times, make_bandwidths
from .worker import LocalTrainer, local_unit_stats, make_batch_plan, plan_steps

__all__ = [
    "SimConfig", "SimResult", "RegrowConfig", "run_simulation", "default_cnn",
]

_DATA_DEP_IMPORTANCE = ("l1", "taylor", "fpgm", "hrank")


def default_cnn() -> CNNConfig:
    """Small VGG used by the CPU-budget simulations (same family as VGG16)."""
    return vgg_config("vgg_sim", [32, "M", 64, "M", 64], num_classes=10, image_size=16)


@dataclasses.dataclass(frozen=True)
class RegrowConfig:
    """FedDST-style mask readjustment (arXiv:2112.09824; ROADMAP item 4).

    Every ``interval`` rounds, each worker with retention < 1 prunes
    ``alpha_t`` of its retained parameters by GLOBAL weight magnitude, then
    grows the exact same parameter budget back from its absent units, ranked
    by gradient magnitude of the dense model at the aggregated global on the
    worker's own shard (the RigL/FedDST grow signal — pruned slots carry
    real gradients there).  ``alpha_t`` follows FedDST's cosine anneal
    ``0.5 * alpha0 * (1 + cos(pi * (t-1) / T))`` (``schedule="cosine"``) or
    stays at ``alpha0`` (``schedule="constant"``).

    Readjustment happens at the START of a round, BEFORE broadcast-back, so
    grown units inherit their global values for free on the resident engines
    (``theta_g[None] * M`` scatters into the fresh mask) — a mask-row
    rewrite with zero recompiles.  Retention is ~unchanged (the grow budget
    equals the shrink's removed mass, overshoot < one unit cost), so Alg. 2
    pruned-rate histories keep monotone gammas up to that sliver — the
    recency-capped Newton guard absorbs the rest."""

    interval: int = 4          # R_adj: rounds between mask readjustments
    alpha0: float = 0.3        # initial readjust fraction
    schedule: str = "cosine"   # "cosine" | "constant"

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError(f"regrow interval {self.interval} must be >= 1")
        if not (0.0 < self.alpha0 < 1.0):
            raise ValueError(f"regrow alpha0 {self.alpha0} outside (0, 1)")
        if self.schedule not in ("cosine", "constant"):
            raise ValueError(
                f"regrow schedule {self.schedule!r} not in cosine/constant"
            )


@dataclasses.dataclass
class SimConfig:
    method: str = "adaptcl"
    rounds: int = 30
    num_workers: int = 10
    local_epochs: float = 1.0
    batch_size: int = 32
    lr: float = 0.05
    lam: float = 1e-4                   # group-lasso coefficient (sparse train)
    prune_interval: int = 5             # PI (paper: 10, T=150; scaled T=30)
    beta: float = 1.0                   # pruning position within local epochs
    importance: str = "cig_bnscalor"
    aggregation: str = "by_worker"
    rate_cfg: PrunedRateConfig = dataclasses.field(default_factory=PrunedRateConfig)
    het: HeterogeneityConfig = dataclasses.field(default_factory=HeterogeneityConfig)
    t_train_full: float = 1.0           # seconds per local round, full model
    train_sens: float = 0.1             # Appendix E: GPU-like ~0, CPU-like ~1
    time_jitter: float = 0.02
    noniid_s: float = 0.0               # paper's s%: 0 (IID) or 80
    ssp_threshold: int = 2
    fedasync_a: float = 0.5
    dcasgd_lambda: float = 2.0
    dcasgd_m: float = 0.95
    fixed_pruned_rates: Optional[List[List[float]]] = None  # Tab. IX mode
    # AdaptCL+DGC (Appendix E / Tab. XVII): commit only the largest
    # (1-sparsity) fraction of each weight delta; the rest accumulates
    # locally until it crosses the threshold (momentum-factor-masking lite).
    dgc_sparsity: float = 0.0
    # FedDST-style mask regrowth (RegrowConfig); None = monotone pruning
    # only.  Applies to the synchronous methods under every engine; regrow
    # rounds cut fused chunks so the readjustment runs at a host boundary.
    regrow: Optional[RegrowConfig] = None
    # local-training engine: "sequential" | "bucketed" | "masked" | "fused"
    # (core.fleet; "fused" = the resident stacks PLUS chunked on-device
    # round fusion, core.fused)
    engine: str = "sequential"
    # fused engine: max rounds per lax.scan chunk (0 = auto: fuse up to the
    # next host boundary — a prune-rate-learning event for adaptcl, 8 rounds
    # otherwise).  Chunks always end at learning events and churn rounds.
    round_fusion: int = 0
    # opt-in cross-round momentum: the resident momentum stack becomes a
    # true optimizer carry across phases AND rounds (masked/fused engines
    # only) instead of the per-phase zero restart of the reference engines
    resident_momentum: bool = False
    # device compute path of the masked engine's programs: "dense" executes
    # base-shape convs under 0/1 masks (full FLOPs), "block_skip" dispatches
    # convs + head through kernels.pruned_matmul so device FLOPs track
    # retention (requires engine="masked"; interpret-mode fallback off-TPU)
    compute: str = "dense"
    # pruned_matmul tile sizes (block_m, block_n, block_k); 128-aligned on
    # TPU, shrink for fine-grained CPU/interpret runs and small models
    compute_blocks: Tuple[int, int, int] = (128, 128, 128)
    # client sampling / dropout / churn (core.scenario); async methods
    # honour sampling + dropout (timed-out commits) and reject churn
    scenario: Optional[ScenarioConfig] = None
    # robust server aggregation (core.aggregation.RobustAggConfig): per-commit
    # L2 norm clipping, coordinate-wise trimmed mean, and the MAD-outlier
    # quarantine health tracker.  by_worker aggregation only; async methods
    # support clip + quarantine and reject trim by name.  None = the plain
    # capability-weighted mean, bit-identical to pre-feature.
    robust: Optional[RobustAggConfig] = None
    # async engines: event-queue commits landing within this virtual window
    # batch into ONE fleet call (0.0 = serial, exactly the legacy behavior)
    async_window: float = 0.0
    # mesh-sharded fleet (fused sync engine only): a 1-D device mesh with a
    # ``fleet_axis`` axis (launch.mesh.make_fleet_mesh) shards every
    # resident [W, ...] stack as W = n_dev x W_local and runs each scan
    # chunk as one program PER SHARD with on-mesh two-tier aggregation
    # (core.fused / sharding.specs.fleet_sharding).  None = single device.
    mesh: Optional[object] = None
    fleet_axis: str = "fleet"
    cnn: CNNConfig = dataclasses.field(default_factory=default_cnn)
    task: Optional[SyntheticImageTask] = None
    eval_every: int = 1
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    method: str
    acc_time: List[Tuple[float, float]]         # (virtual seconds, test acc)
    final_acc: float
    best_acc: float
    best_acc_time: float
    total_time: float
    het_traj: List[Tuple[int, float]]            # (round, H of update times)
    retentions: List[float]                      # final gamma per worker
    param_reduction: float                       # avg over workers
    flops_reduction: float
    comm_bytes: float
    server_overhead_s: float                     # Alg.2 + aggregation walltime
    recompiles: int
    similarity_traj: List[Tuple[int, float]]     # Eq. 3 between two workers
    update_times: List[List[float]]              # per round, per worker
    engine: str = "sequential"                   # fleet engine that ran it
    batched_calls: int = 0                       # vmapped device programs
    walltime_s: float = 0.0                      # host wall-clock of the run
    host_roundtrips: int = 0                     # extract/embed in round loop
    # (round, n_active, n_dropped, n_joined) per round when a scenario ran
    scenario_rounds: List[Tuple[int, int, int, int]] = dataclasses.field(
        default_factory=list
    )
    # sub-stack row buckets launched by the resident engine (sorted); the
    # recompile count is bounded by len(bucket_sizes) x phases
    bucket_sizes: List[int] = dataclasses.field(default_factory=list)
    # device compute path ("dense" | "block_skip") + the training-FLOPs
    # ledger: flops_ideal is the paper's per-sub-model cost
    # (cnn_flops_from_shapes of each worker's reconfigured shapes x images
    # trained), flops_executed the per-worker dispatched cost — equal to
    # ideal for physically reconfigured engines, the full base-shape cost
    # for masked+dense, and the block-granular proxy
    # (models.cnn.cnn_block_compute) for masked+block_skip.  blocks_executed
    # counts kernel grid cells whose MXU pass runs (the interpret-mode proxy
    # benches assert on).  The ledger counts each worker's SCHEDULED plan
    # steps x batch images; the resident engine's compute-and-discard padding
    # (step pads to the per-phase max, pow2 bucket-row pads) is excluded —
    # identical across compute paths, so ratios between them are unaffected.
    compute: str = "dense"
    flops_executed: float = 0.0
    flops_ideal: float = 0.0
    blocks_executed: float = 0.0
    # steady-state per-image cost at the FINAL sub-models (mean over workers)
    # — what a post-prune training step executes, free of warm-up rounds
    flops_per_image_final: float = 0.0
    blocks_per_image_final: float = 0.0
    # jitted training/round programs LAUNCHED (one per device dispatch): the
    # resident engine pays O(rounds) of these, the fused engine
    # O(rounds / round_fusion) — the companion metric to host_roundtrips
    host_dispatches: int = 0
    # wall spent inside FIRST calls of each compiled signature (trace +
    # compile + one execution) — subtract from walltime_s for steady-state
    compile_walltime_s: float = 0.0
    # fused engine: number of lax.scan chunk programs launched
    fused_chunks: int = 0
    # mesh the run executed on (SimConfig.mesh): total devices, fleet-axis
    # extent, and the [W, ...] stack PartitionSpec — 1/1/None on
    # single-device runs, so every BENCH row records its mesh
    n_devices: int = 1
    fleet_axis_size: int = 1
    shard_spec: Optional[str] = None
    # every pruning event: (round, worker, {layer: retained unit ids}) —
    # what the cross-engine bit-identity tests compare round-by-round
    prune_events: List[Tuple[int, int, Dict[str, tuple]]] = dataclasses.field(
        default_factory=list
    )
    # fault-injection ledger (core.faults.fault_ledger): all zeros on
    # fault-free runs; identical across engines under the same fault stream
    # since every engine derives it from the one shared event sequence
    drift_events: int = 0        # drift-multiplier changes (re-learning triggers)
    rounds_degraded: int = 0     # rounds aggregating a fault-reduced cohort
    rounds_skipped: int = 0      # rounds skipped: submitters < min_participants
    workers_recovered: int = 0   # offline->online transitions
    retry_total: int = 0         # re-join rounds trained without aggregation
    byz_commits: int = 0         # submitted commits from compromised workers
    lost_commits: int = 0        # channel drops surviving every retry
    dup_commits: int = 0         # delivered commits duplicated by the channel
    corrupt_commits: int = 0     # delivered commits with garbled payloads
    # robust-aggregation observability: commits excluded by the quarantine
    # health tracker (sync: quarantined submitter-rounds; async: rejected
    # commits) — 0 whenever SimConfig.robust has no quarantine
    quarantined_commits: int = 0
    # final global model (base coordinates) — test/analysis hook
    global_params: Optional[Dict[str, np.ndarray]] = None


def _env_accuracy(env: "_Env", params) -> float:
    """Test accuracy of a base-shape global model through the trainer's jit
    cache: one compiled program per test-batch shape instead of op-by-op
    dispatch (which paid an untracked trace+compile tax on every run).
    Counted like any other dispatch, so ``host_dispatches`` and
    ``compile_walltime_s`` stay honest across engines."""
    cfg = env.sim.cnn
    x, y = env.task.x_test, env.task.y_test
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    correct = 0
    for i in range(0, len(x), 256):
        xb = x[i : i + 256]
        logits = env.trainer._call_cached(
            ("eval_logits", xb.shape),
            lambda: jax.jit(lambda p, q: cnn_apply(p, cfg, q)),
            jp, jnp.asarray(xb),
            count_compile=False,
        )
        correct += int((np.argmax(np.asarray(logits), -1) == y[i : i + 256]).sum())
    return correct / len(x)


class _Env:
    """Shared experimental fixture (same across all methods, per seed)."""

    def __init__(self, sim: SimConfig):
        self.sim = sim
        if sim.compute == "block_skip" and sim.engine != "masked":
            raise ValueError(
                "compute='block_skip' needs the masked (resident) engine — "
                "the block-keep flags are derived from the 0/1 mask stacks; "
                "the reconfigured engines already run physically small "
                "models, and the fused engine's scan does not carry the "
                "interpret-mode kernel off-TPU"
            )
        if sim.resident_momentum and sim.engine not in ("masked", "fused"):
            raise ValueError(
                "resident_momentum needs a resident engine "
                "(engine='masked' or 'fused') — the cross-round carry IS "
                "the FleetState momentum stack"
            )
        if sim.regrow is not None and sim.method not in (
            "adaptcl", "fedavg", "fedavg_s"
        ):
            raise ValueError(
                "SimConfig.regrow (FedDST mask readjustment) applies to the "
                "synchronous methods only — async workers never prune, so "
                "there is nothing to regrow"
            )
        if sim.mesh is not None and (
            sim.engine != "fused"
            or sim.method not in ("adaptcl", "fedavg", "fedavg_s")
        ):
            raise ValueError(
                "SimConfig.mesh (the mesh-sharded fleet) requires the fused "
                "SYNC engine (engine='fused', method in adaptcl/fedavg/"
                "fedavg_s) — the sharded path is the per-shard lax.scan "
                "chunk program with on-mesh aggregation (core.fused)"
            )
        if sim.robust is not None and sim.aggregation != "by_worker":
            raise ValueError(
                "SimConfig.robust (clip/trimmed-mean/quarantine) requires "
                "aggregation='by_worker' — the robust layer defends "
                "per-worker commit deltas, and by_unit's per-coordinate "
                f"holder counts have no delta to clip; got "
                f"aggregation={sim.aggregation!r}"
            )
        _flts = (
            sim.scenario.faults
            if sim.scenario is not None and sim.scenario.faults is not None
            else None
        )
        if _flts is not None and sim.aggregation != "by_worker":
            for fam in ("byzantine", "channel"):
                if getattr(_flts, fam, None) is not None:
                    raise ValueError(
                        f"FaultConfig.{fam} perturbs per-worker commit "
                        "deltas and requires aggregation='by_worker'; got "
                        f"aggregation={sim.aggregation!r}"
                    )
        skew = sim.scenario.skew if sim.scenario is not None else None
        if skew is not None and sim.noniid_s > 0.0:
            raise ValueError(
                "ScenarioConfig.skew (Dirichlet label concentration) and "
                f"SimConfig.noniid_s={sim.noniid_s} are competing Non-IID "
                "partitioners — set exactly one"
            )
        self.task = sim.task or SyntheticImageTask(
            num_classes=sim.cnn.num_classes, image_size=sim.cnn.image_size,
            train_size=1280, test_size=512, seed=sim.seed,
        )
        self.shards = (
            partition_dirichlet(
                self.task.y_train, sim.num_workers, skew, seed=sim.seed
            )
            if skew is not None
            else partition_noniid(
                self.task.y_train, sim.num_workers, sim.noniid_s, seed=sim.seed
            )
        )
        key = jax.random.PRNGKey(sim.seed)
        self.base_params = {k: np.asarray(v) for k, v in init_cnn(key, sim.cnn).items()}
        self.base_shapes = {k: v.shape for k, v in self.base_params.items()}
        self.space, self.unit_map = build_unit_space(sim.cnn, self.base_params)
        self.full_bytes = payload_bytes(full_index(self.space), self.space)
        self.full_flops = cnn_flops(self.base_params, sim.cnn)
        self.bandwidths = make_bandwidths(sim.het, self.full_bytes, sim.t_train_full)
        self.trainer = LocalTrainer(
            sim.cnn, lr=sim.lr,
            compute=sim.compute, compute_blocks=sim.compute_blocks,
        )
        self.fleet = FleetEngine(
            self.trainer, self.unit_map, self.base_shapes, engine=sim.engine
        )
        self.rng = np.random.default_rng(sim.seed + 17)
        # training-FLOPs ledger (SimResult.flops_*): per-image costs are
        # cached per distinct global index, multiplied by images trained
        self.flops_executed = 0.0
        self.flops_ideal = 0.0
        self.blocks_executed = 0.0
        self._acct_cache: Dict[tuple, Tuple[float, float, float]] = {}

    def cost_for_index(self, index) -> Tuple[float, float, float]:
        """(executed flops, ideal flops, executed kernel blocks) per IMAGE at
        this global index, for the engine/compute path this run dispatches."""
        key = tuple(
            (l, tuple(map(int, v))) for l, v in sorted(index.items())
        )
        cached = self._acct_cache.get(key)
        if cached is None:
            shapes = subparam_shapes(index, self.unit_map, self.base_shapes)
            ideal = cnn_flops_from_shapes(shapes, self.sim.cnn)
            if self.sim.compute == "block_skip":
                masks = {
                    l.name: np.asarray(
                        np.isin(np.arange(l.num_units), index[l.name]), np.float32
                    )
                    for l in self.space.layers
                }
                bc = cnn_block_compute(self.sim.cnn, masks, self.sim.compute_blocks)
                cached = (bc["flops"], ideal, bc["blocks"])
            elif self.sim.engine in ("masked", "fused"):
                # dense masked programs run the base shapes regardless of masks
                cached = (self.full_flops, ideal, 0.0)
            else:
                # physically reconfigured models execute exactly their size
                cached = (ideal, ideal, 0.0)
            self._acct_cache[key] = cached
        return cached

    def account_train(self, index, steps: int):
        """Record one worker's local-training phase in the FLOPs ledger:
        ``steps`` plan steps x batch images, costed at this global index
        (scheduled work only — the resident engine's compute-and-discard
        step/bucket padding is not attributed to any worker)."""
        if steps <= 0:
            return
        executed, ideal, blocks = self.cost_for_index(index)
        images = steps * self.sim.batch_size
        self.flops_executed += images * executed
        self.flops_ideal += images * ideal
        self.blocks_executed += images * blocks

    def phi(self, worker: int, params, payload_factor: float = 1.0) -> float:
        """Channel-model update time for this worker's current sub-model."""
        return self._phi_from_shapes(
            worker, {k: v.shape for k, v in params.items()}, payload_factor
        )

    def phi_from_index(
        self, worker: int, index, payload_factor: float = 1.0, jitter: bool = True,
        time_mult: float = 1.0,
    ) -> float:
        """Channel-model time from the global index alone — the resident
        engine's path: payload bytes and FLOPs derive from the reconfigured
        SHAPES (``subparam_shapes``), no arrays are materialized."""
        return self._phi_from_shapes(
            worker,
            subparam_shapes(index, self.unit_map, self.base_shapes),
            payload_factor,
            jitter,
            time_mult,
        )

    def _phi_from_shapes(
        self, worker, shapes, payload_factor, jitter=True, time_mult=1.0
    ) -> float:
        sim = self.sim
        bytes_raw = sum(int(np.prod(s)) * 4 for s in shapes.values())
        flops_w = cnn_flops_from_shapes(shapes, sim.cnn)
        jmult = (
            float(np.exp(self.rng.normal(0, sim.time_jitter)))
            if jitter and sim.time_jitter > 0 else 1.0
        )
        # capability drift folds into the same multiplicative slot as the
        # jitter, so the fused path (which pre-draws jitters and multiplies
        # the drift curve in on host) reproduces the product bit for bit
        return self.phi_from_cost(
            worker, bytes_raw, flops_w, payload_factor, jmult * time_mult
        )

    def phi_from_cost(
        self, worker: int, bytes_raw: int, flops_w: float,
        payload_factor: float = 1.0, jitter_mult: float = 1.0,
    ) -> float:
        """The Eq. 6/7 channel model from precomputed payload bytes + FLOPs.

        The ONE implementation behind both the lazy per-round path
        (``_phi_from_shapes``, which derives the costs from shapes and draws
        its jitter) and the fused engine's cached path (costs memoized per
        retained-count signature, jitter pre-drawn) — so the two can't
        drift and clocks stay engine-identical."""
        sim = self.sim
        bytes_w = payload_factor * bytes_raw
        rel = flops_w / self.full_flops
        t_train = sim.t_train_full * ((1 - sim.train_sens) + sim.train_sens * rel)
        t = 2.0 * bytes_w / self.bandwidths[worker] + t_train * sim.local_epochs
        return t * jitter_mult

    def shard_xy(self, w):
        sh = self.shards[w]
        return self.task.x_train[sh], self.task.y_train[sh]


# ---------------------------------------------------------------------------
# synchronous methods: fedavg / fedavg_s / adaptcl
# ---------------------------------------------------------------------------

def _dgc_compress(delta: Dict[str, np.ndarray], residual: Dict[str, np.ndarray],
                  sparsity: float):
    """Top-|.| delta sparsification with local residual accumulation ([11]).

    Returns (committed delta, new residual, kept-fraction payload factor).

    A reconfiguration that changed a tensor's shape restarts DGC's
    accumulators for it (momentum-factor-masking semantics): the stale
    residual is dropped AND the tensor commits densely this round, so the
    kept-fraction accounting is reset too — the payload factor honestly
    reflects the dense warm-up commit instead of silently reporting the
    steady-state sparsity."""
    committed, new_res = {}, {}
    kept = total = 0
    for k, d in delta.items():
        r = residual.get(k)
        restarted = r is not None and r.shape != d.shape
        if r is not None and not restarted:
            d = d + r
        if restarted:
            committed[k], new_res[k] = d, np.zeros_like(d)
            kept += d.size
            total += d.size
            continue
        flat = np.abs(d).ravel()
        # keep budget in float32 — the SAME rounding the device compressor
        # (aggregation.dgc_compress_jnp) performs, so keep sets can't diverge
        # on half-integer budgets
        n_keep = max(
            1, int(np.round(np.float32(flat.size) * np.float32(1.0 - sparsity)))
        )
        if n_keep >= flat.size:
            committed[k], new_res[k] = d, np.zeros_like(d)
            kept += flat.size
        else:
            thr = np.partition(flat, flat.size - n_keep)[flat.size - n_keep]
            mask = np.abs(d) >= thr
            committed[k] = d * mask
            new_res[k] = d * (1.0 - mask)
            # ties at the threshold all commit (>=), so count the REALIZED
            # mask — n_keep undercounts exactly when |delta| values collide
            kept += int(mask.sum())
        total += flat.size
    # payload: kept values + their indices (~1.25x values, as in DGC)
    return committed, new_res, 1.25 * kept / max(total, 1)


def _dgc_compress_stacked(
    delta: Dict[str, np.ndarray],        # {path: [W, ...]} base-coord deltas
    residual: Dict[str, np.ndarray],     # {path: [W, ...]} accumulators
    sparsity: float,
    masks: Optional[Dict[str, np.ndarray]] = None,   # {path: [W, ...]} 0/1
    rows: Optional[np.ndarray] = None,               # bool [W]: rows to commit
):
    """Vectorized DGC over the resident ``[W, ...]`` delta stacks.

    Per tensor, the top-|.| threshold is computed per worker row in one
    ``np.sort`` over the flattened ``[W, N]`` view.  ``masks`` makes the
    compressor mask-aware: each worker's keep budget is a fraction of its
    RETAINED coordinate count (matching the per-worker compressor applied to
    the reconfigured tensor), pruned coordinates are never committed, and the
    residual is kept only on retained coordinates (pruning zeroes a worker's
    residual on the units it lost — nothing else restarts, unlike the
    shape-changing per-worker path, because resident shapes never change).
    ``rows`` limits commits to the submitting workers; others keep their
    residual untouched and report payload factor 1.0.

    Returns (committed stacks, new residual stacks, factors ``[W]``)."""
    W = next(iter(delta.values())).shape[0]
    rows = np.ones(W, bool) if rows is None else np.asarray(rows, bool)
    committed: Dict[str, np.ndarray] = {}
    new_res: Dict[str, np.ndarray] = {}
    kept = np.zeros(W)
    total = np.zeros(W)
    for k, d in delta.items():
        r = residual.get(k)
        acc = d if r is None else d + r
        flat = acc.reshape(W, -1)
        absf = np.abs(flat)
        if masks is not None:
            valid = masks[k].reshape(W, -1) > 0
            sizes = valid.sum(axis=1)
            absf = np.where(valid, absf, -1.0)
        else:
            valid = None
            sizes = np.full(W, flat.shape[1])
        # float32 keep budgets, matching aggregation.dgc_compress_jnp exactly
        n_keep = np.maximum(
            1,
            np.round(
                sizes.astype(np.float32) * np.float32(1.0 - sparsity)
            ).astype(np.int64),
        )
        n_keep = np.minimum(n_keep, np.maximum(sizes, 1))
        order = np.sort(absf, axis=1)[:, ::-1]
        thr = order[np.arange(W), n_keep - 1]
        keep = absf >= thr[:, None]
        if valid is not None:
            keep &= valid
        com = np.where(keep, flat, 0.0)
        res = np.where(keep, 0.0, flat)
        if valid is not None:
            res = np.where(valid, res, 0.0)
        old_res = np.zeros_like(flat) if r is None else r.reshape(W, -1)
        rowsf = rows[:, None]
        committed[k] = np.where(rowsf, com, 0.0).reshape(d.shape).astype(d.dtype)
        new_res[k] = np.where(rowsf, res, old_res).reshape(d.shape).astype(d.dtype)
        # realized per-row commit counts: ties at the threshold all pass the
        # >= test, and a fully-masked row (sizes == 0) commits nothing — the
        # keep mask already reflects both, n_keep reflects neither
        kept += np.where(rows, keep.sum(axis=1), 0)
        total += np.where(rows, sizes, 0)
    factors = np.where(rows, 1.25 * kept / np.maximum(total, 1), 1.0)
    return committed, new_res, factors


def _regrow_alpha(cfg: RegrowConfig, t: int, rounds: int) -> float:
    """Readjust fraction in force at the start of round t (FedDST anneal)."""
    if cfg.schedule == "constant":
        return cfg.alpha0
    return float(
        0.5 * cfg.alpha0 * (1.0 + np.cos(np.pi * (t - 1) / max(rounds, 1)))
    )


def _regrow_round(sim: SimConfig, t: int) -> bool:
    """Does a mask readjustment fire at the START of round t?  Every
    ``interval`` completed rounds — so the first possible event is the start
    of round ``interval + 1``, operating on a freshly aggregated global."""
    return (
        sim.regrow is not None
        and t > 1
        and (t - 1) % sim.regrow.interval == 0
    )


def _weight_magnitude_scores(params, unit_map, unit_counts) -> Dict[str, np.ndarray]:
    """Per-unit L2 group norms of a base-coordinate param dict (float64) —
    the shrink half of the readjustment ranks retained units by the GLOBAL
    model's weight magnitude, so the order is one shared host computation
    per regrow round, identical for every engine."""
    acc = {k: np.zeros(n, np.float64) for k, n in unit_counts.items()}
    for path, entries in unit_map.items():
        arr = params.get(path)
        if arr is None:
            continue
        sq = np.asarray(arr, np.float64) ** 2
        for lname, axis in entries:
            if lname not in acc:
                continue
            axes = tuple(i for i in range(sq.ndim) if i != axis)
            acc[lname] += sq.sum(axis=axes)
    return {k: np.sqrt(v) for k, v in acc.items()}


def _regrow_step(
    sim: SimConfig, env: _Env, global_params, indices, t: int
) -> List[Tuple[int, Dict[str, np.ndarray]]]:
    """One FedDST mask readjustment at the start of round t (host math).

    Per worker with retention < 1: ``prune_to_budget`` removes ``alpha_t``
    of the retained parameter mass by global weight magnitude, then
    ``regrow_index`` adds the SAME integer parameter budget back from the
    absent units, ranked by |grad| of the dense model at the global on the
    worker's shard head (``trainer.gradient`` — one extra jit signature,
    cached across all regrow events).  Consumes NO ``env.rng`` draws, so
    the plan/jitter streams — and therefore everything a regrow-disabled
    run computes — are untouched.

    Returns ``[(worker, new_index)]`` for the readjusted workers; the
    caller records them in ``prune_events`` and refreshes device masks."""
    cfg = sim.regrow
    alpha_t = _regrow_alpha(cfg, t, sim.rounds)
    if alpha_t <= 0.0:
        return []
    shrink_scores = None
    out: List[Tuple[int, Dict[str, np.ndarray]]] = []
    for w in range(sim.num_workers):
        if retention(indices[w], env.space) >= 1.0:
            continue   # full model: no absent units to grow back
        if shrink_scores is None:
            shrink_scores = _weight_magnitude_scores(
                global_params, env.unit_map, env.space.unit_counts
            )
        shrunk = prune_to_budget(indices[w], shrink_scores, alpha_t, env.space)
        budget = sum(
            (len(indices[w][l.name]) - len(shrunk[l.name])) * l.unit_param_cost
            for l in env.space.layers
        )
        if budget <= 0:
            continue
        x, y = env.shard_xy(w)
        grads = env.trainer.gradient(
            {k: np.asarray(v, np.float32) for k, v in global_params.items()},
            env.unit_map, x[:64], y[:64],
        )
        grow_scores = grad_magnitude_scores(
            grads, env.unit_map, env.space.unit_counts
        )
        indices[w] = regrow_index(shrunk, grow_scores, budget, env.space)
        out.append((w, indices[w]))
    return out


def _skip_round_time(env: _Env, scen: ScenarioEngine, indices, round_t: int) -> float:
    """Virtual-clock advance for a SKIPPED round (too few fault survivors to
    aggregate): the server waits out the full straggler deadline —
    ``timeout_factor`` x the slowest nominal update time at the current
    sub-models — then moves on.  Jitter-free and RNG-free, so the lazy and
    fused engines advance identical clocks without consuming any stream."""
    mults = scen.drift_mults(round_t)
    phis = [
        env.phi_from_index(w, indices[w], jitter=False, time_mult=float(mults[w]))
        for w in range(len(indices))
    ]
    return scen.cfg.timeout_factor * max(phis)


def _commit_multiplicity(events) -> np.ndarray:
    """Per-worker commit weight: submit x delivered x (1 + dup), host f64.

    With no channel model this IS the submitter indicator, so dividing by
    its sum reproduces the pre-feature plain-mean weights bit-for-bit."""
    mult = events.submitters.astype(np.float64)
    if events.delivered is not None:
        mult = mult * events.delivered * (1.0 + events.dup)
    return mult


def _robust_aggregate_host(
    agg_stacks, mask_stacks, global_params, mult, events,
    byz_cfg, ch_cfg, corrupt_on, rb_cfg, seed: int, t: int,
    strikes, quar_left,
):
    """Masked-loop twin of the fused robust branch.

    Calls THE same :func:`robust_submission_step_jnp` the fused scan body
    runs, eagerly, on host-fed ``[W, ...]`` stacks — attack transform,
    channel corruption, clip/trim/quarantine and the wsum==0 all-lost-round
    guard are one code path, so robust worlds keep masked == fused by
    construction.  Returns ``(new_global_np, strikes', quar_left',
    quar_now_bool_or_None)``."""
    quar_cfg = rb_cfg.quarantine if rb_cfg is not None else None
    stacks = {
        k: jnp.asarray(np.asarray(v, np.float32)) for k, v in agg_stacks.items()
    }
    masks = (
        {k: jnp.asarray(np.asarray(v, np.float32)) for k, v in mask_stacks.items()}
        if mask_stacks is not None else None
    )
    gl = {
        k: jnp.asarray(np.asarray(v, np.float32))
        for k, v in global_params.items()
    }
    ms = mult.sum()
    weights = (
        (mult / ms).astype(np.float32) if ms > 0
        else np.zeros_like(mult, dtype=np.float32)
    )
    byz_row = None
    if byz_cfg is not None and events.byz is not None:
        byz_row = jnp.asarray(events.byz & events.submitters)
    cor_row = None
    if corrupt_on and events.corrupt is not None:
        cor_row = jnp.asarray(events.corrupt & events.delivered & events.submitters)
    new_g, st2, qu2, quar_now = robust_submission_step_jnp(
        stacks, masks, gl, jnp.asarray(mult.astype(np.float32)),
        jnp.asarray(weights), byz_row, cor_row,
        noise_key(seed + 51721, t) if byz_cfg is not None else None,
        noise_key(seed + 51722, t) if corrupt_on else None,
        strikes, quar_left,
        byz_mode=byz_cfg.mode if byz_cfg is not None else "sign_flip",
        byz_scale=byz_cfg.scale if byz_cfg is not None else -10.0,
        byz_noise_std=byz_cfg.noise_std if byz_cfg is not None else 1.0,
        corrupt_std=ch_cfg.corrupt_std if corrupt_on else 10.0,
        clip=rb_cfg.clip if rb_cfg is not None else None,
        trim=rb_cfg.trim if rb_cfg is not None else 0.0,
        quarantine=quar_cfg,
    )
    out = {k: np.asarray(v) for k, v in new_g.items()}
    quar_np = np.asarray(quar_now) > 0.5 if quar_cfg is not None else None
    return out, st2, qu2, quar_np


def _run_sync(sim: SimConfig, env: _Env) -> SimResult:
    W = sim.num_workers
    sparse = sim.method in ("fedavg_s", "adaptcl")
    adapt = sim.method == "adaptcl"
    lam = sim.lam if sparse else 0.0
    resident = sim.engine == "masked"
    scen = ScenarioEngine(sim.scenario, W) if sim.scenario is not None else None
    # robust-aggregation statics: byzantine / lossy channel / clip-trim-
    # quarantine.  All None => every branch below is the pre-feature one.
    faults_cfg = (
        sim.scenario.faults
        if sim.scenario is not None and sim.scenario.faults is not None
        else None
    )
    byz_cfg = faults_cfg.byzantine if faults_cfg is not None else None
    ch_cfg = faults_cfg.channel if faults_cfg is not None else None
    corrupt_on = ch_cfg is not None and ch_cfg.corrupt > 0.0
    rb_cfg = (
        sim.robust if sim.robust is not None and sim.robust.any_active else None
    )
    quar_cfg = rb_cfg.quarantine if rb_cfg is not None else None
    robust_on = byz_cfg is not None or ch_cfg is not None or rb_cfg is not None
    rb_strikes = rb_quar = None
    if quar_cfg is not None:
        rb_strikes = jnp.zeros(W, jnp.int32)
        rb_quar = jnp.zeros(W, jnp.int32)
    quarantined_commits = 0
    dgc_residuals: List[Dict[str, np.ndarray]] = [{} for _ in range(W)]
    dgc_res_stack: Optional[Dict[str, np.ndarray]] = None

    global_params = dict(env.base_params)
    indices = [full_index(env.space) for _ in range(W)]
    histories = [WorkerHistory() for _ in range(W)]
    pending_rates = [0.0] * W
    cig_scores = None              # frozen at first pruning (CIG principle)
    interval_phis: List[List[float]] = [[] for _ in range(W)]
    prune_round_count = 0
    prune_events: List[Tuple[int, int, Dict[str, tuple]]] = []

    state = None
    pad_a = pad_b = None
    if resident:
        shard_x, shard_y = zip(*(env.shard_xy(w) for w in range(W)))
        state = env.fleet.init_state(env.base_params, list(shard_x), list(shard_y))
        if sim.resident_momentum:
            env.fleet.init_momentum(state)
        # constant per-phase step pads (churn keeps shard sizes fixed): every
        # gathered sub-stack shares one plan shape per phase, so recompiles
        # are bounded by the row buckets alone
        pad_a = max(
            plan_steps(len(env.shards[w]), sim.batch_size, sim.local_epochs)
            for w in range(W)
        )
        pad_b = max(
            plan_steps(
                len(env.shards[w]), sim.batch_size,
                (1 - sim.beta) * sim.local_epochs,
            )
            for w in range(W)
        )
        if sim.dgc_sparsity > 0.0:
            dgc_res_stack = {
                k: np.zeros((W,) + tuple(s), np.float32)
                for k, s in env.base_shapes.items()
            }

    clock = 0.0
    comm_bytes = 0.0
    server_overhead = 0.0
    acc_time, het_traj, sim_traj, upd_times = [], [], [], []
    scen_rows: List[Tuple[int, int, int, int]] = []
    events_log: List = []
    acc0 = _env_accuracy(env, global_params)
    acc_time.append((0.0, acc0))
    rt_base = roundtrip_total()    # host extract/embed round-trips in the loop

    def _learn_rates(t: int, drift_trigger: bool):
        """One Alg. 2 server step (pruning-interval boundary OR a capability
        drift event).  Drift re-learning invalidates the drifted worker's
        (gamma, phi) history first — those pairs describe a capability that
        no longer exists — so it re-enters through the bootstrap path."""
        nonlocal prune_round_count, cig_scores, pending_rates, interval_phis
        prune_round_count += 1
        if cig_scores is None and sim.importance == "cig_bnscalor":
            cig_scores = METHODS["cig_bnscalor"](ImportanceContext(
                unit_counts=env.space.unit_counts,
                scales=extract_bn_scales(global_params, sim.cnn),
            ))
        if drift_trigger:
            histories[sim.scenario.faults.drift.worker].invalidate()
        mults = scen.drift_mults(t) if scen is not None else np.ones(W)
        gammas_now = [retention(indices[w], env.space) for w in range(W)]
        phis_now = [
            float(np.mean(interval_phis[w])) if interval_phis[w]
            else env.phi_from_index(
                w, indices[w], jitter=False, time_mult=float(mults[w])
            )
            for w in range(W)
        ]
        for w in range(W):
            histories[w].record(gammas_now[w], phis_now[w])
        if sim.fixed_pruned_rates is not None:
            k = prune_round_count - 1
            rates = (
                sim.fixed_pruned_rates[k]
                if k < len(sim.fixed_pruned_rates)
                else [0.0] * W
            )
        else:
            rates = learn_pruned_rates(histories, gammas_now, phis_now, sim.rate_cfg)
        pending_rates = list(rates)
        interval_phis = [[] for _ in range(W)]

    for t in range(1, sim.rounds + 1):
        events = scen.draw(t) if scen is not None else full_participation(W)
        events_log.append(events)
        # --- churn: replaced slots restart as fresh full-model workers.
        if events.joined.any():
            for w in np.flatnonzero(events.joined):
                indices[w] = full_index(env.space)
                histories[w] = WorkerHistory()
                pending_rates[w] = 0.0
                dgc_residuals[w] = {}
                interval_phis[w] = []
                if dgc_res_stack is not None:
                    for k in dgc_res_stack:
                        dgc_res_stack[k][w] = 0.0
                env.shards[w] = scen.fresh_shard(
                    len(env.shards[w]), len(env.task.y_train)
                )
                if resident:
                    env.fleet.update_shard(state, int(w), *env.shard_xy(int(w)))
                    if sim.resident_momentum:
                        # a churned-in worker is a FRESH worker: its slot's
                        # cross-round velocity restarts at zero
                        state.momentum = {
                            k: v.at[int(w)].set(0.0)
                            for k, v in state.momentum.items()
                        }
            if resident:
                env.fleet.refresh_masks(state, indices)
        # --- crash recovery: a returning worker refetches the current global
        # (the ordinary broadcast-back covers that) and re-enters with its
        # LAST mask and history, but velocity/residuals accumulated against
        # pre-crash parameters restart at zero.
        if events.recovered is not None and events.recovered.any():
            rec_ws = [int(w) for w in np.flatnonzero(events.recovered)]
            for w in rec_ws:
                dgc_residuals[w] = {}
                if dgc_res_stack is not None:
                    for k in dgc_res_stack:
                        dgc_res_stack[k][w] = 0.0
            if resident and sim.resident_momentum:
                env.fleet.zero_momentum_rows(state, rec_ws)
        active_ws = [int(w) for w in np.flatnonzero(events.active)]
        if scen is not None:
            scen_rows.append((
                t, len(active_ws), int(events.dropped.sum()), int(events.joined.sum()),
            ))

        # --- FedDST mask readjustment at the round start, BEFORE
        # broadcast-back: grown units inherit their global values for free.
        # On the resident engine this is a pure mask-row rewrite.
        if _regrow_round(sim, t):
            regrown = _regrow_step(sim, env, global_params, indices, t)
            for w, idx_w in regrown:
                prune_events.append((
                    t, int(w),
                    {k: tuple(map(int, v)) for k, v in idx_w.items()},
                ))
            if resident and regrown:
                env.fleet.refresh_masks(state, indices)

        # --- graceful degradation floor: too few fault survivors to
        # aggregate.  Nothing trains, the global is untouched, and the
        # virtual clock waits out the straggler deadline — then the round
        # ends (no hang, no exception).  Server-side steps that do not need
        # submissions (Alg. 2 at an interval boundary, evals) still run, so
        # the fused engine's chunk boundaries see the same state.
        if events.skip:
            clock += _skip_round_time(env, scen, indices, t)
            upd_times.append([float("nan")] * W)
            t0 = _time.perf_counter()
            if adapt and (t % sim.prune_interval == 0 or events.drift_changed):
                _learn_rates(t, events.drift_changed)
            server_overhead += _time.perf_counter() - t0
            if t % sim.eval_every == 0:
                acc_time.append((clock, _env_accuracy(env, global_params)))
            continue

        # --- batch plans, drawn in worker order up front so the batch
        # sequences (and therefore the trained models) are identical across
        # engines.
        plans_a: List[Optional[np.ndarray]] = [None] * W
        plans_b: List[Optional[np.ndarray]] = [None] * W
        prune_now = [False] * W
        for w in active_ws:
            rate = pending_rates[w] if adapt else 0.0
            if adapt and rate > 0.0:
                e1, e2 = sim.beta * sim.local_epochs, (1 - sim.beta) * sim.local_epochs
                prune_now[w] = True
            else:
                e1, e2 = sim.local_epochs, 0.0
            n = len(env.shards[w])
            plans_a[w] = make_batch_plan(n, sim.batch_size, e1, env.rng)
            plans_b[w] = make_batch_plan(n, sim.batch_size, e2, env.rng)
        for w in active_ws:   # FLOPs ledger: phase A runs at the pre-prune index
            env.account_train(indices[w], plans_a[w].shape[0])

        # --- phase A: every participating worker's pre-prune local training,
        # ONE fleet call.  Resident path: broadcast-back is a masked scatter
        # into the [W, ...] stacks, then one vmapped program over the stack.
        worker_params: Dict[int, Dict[str, np.ndarray]] = {}
        if resident:
            env.fleet.scatter_global(state, global_params)
            env.fleet.train_rounds(
                state, plans_a, lam, pad_steps=pad_a,
                carry_momentum=sim.resident_momentum,
            )
        else:
            jobs_a = []
            for w in active_ws:
                x, y = env.shard_xy(w)
                jobs_a.append(FleetJob(
                    worker=w,
                    params=extract_subparams(global_params, indices[w], env.unit_map),
                    index=indices[w], x=x, y=y, plan=plans_a[w],
                ))
            for w, p in zip(active_ws, env.fleet.train_all(jobs_a, lam)):
                worker_params[w] = p

        # --- phase B: pruning workers prune/reconfigure at position beta,
        # then finish their remaining epochs (second fleet call).  Resident:
        # pruning only rewrites mask rows — shapes never change.
        jobs_b: List[FleetJob] = []
        pruned_any = False
        for w in active_ws:
            if not prune_now[w]:
                continue
            scores = _scores_for(
                sim, env, w, prune_round_count,
                worker_params.get(w), indices[w], cig_scores, state,
            )
            if resident:
                indices[w] = prune_to_budget(
                    indices[w], scores, pending_rates[w], env.space
                )
                pruned_any = True
            else:
                worker_params[w], indices[w] = env.trainer.prune_and_reconfigure(
                    worker_params[w], indices[w], scores, pending_rates[w],
                    env.space, env.unit_map,
                )
                if plans_b[w].shape[0] > 0:
                    x, y = env.shard_xy(w)
                    jobs_b.append(FleetJob(
                        worker=w, params=worker_params[w], index=indices[w],
                        x=x, y=y, plan=plans_b[w],
                    ))
            prune_events.append((
                t, int(w),
                {k: tuple(map(int, v)) for k, v in indices[w].items()},
            ))
        if resident:
            if pruned_any:
                env.fleet.refresh_masks(state, indices)
                env.fleet.train_rounds(
                    state,
                    [plans_b[w] if prune_now[w] else None for w in range(W)],
                    lam, pad_steps=pad_b,
                    carry_momentum=sim.resident_momentum,
                )
        elif jobs_b:
            for job, trained in zip(jobs_b, env.fleet.train_all(jobs_b, lam)):
                worker_params[job.worker] = trained
        for w in active_ws:   # FLOPs ledger: phase B runs at the pruned index
            if prune_now[w]:
                env.account_train(indices[w], plans_b[w].shape[0])

        # --- submission boundary: channel model + (optional) DGC delta
        # compression + aggregation inputs.
        submitters = events.submitters
        payload = np.ones(W)
        agg_stacks = None
        if resident:
            if sim.dgc_sparsity > 0.0:
                P = env.fleet.params_host(state)
                M = env.fleet.masks_host(state)
                deltas = {
                    k: P[k] - np.asarray(global_params[k], np.float32)[None] * M[k]
                    for k in P
                }
                committed, dgc_res_stack, payload = _dgc_compress_stacked(
                    deltas, dgc_res_stack, sim.dgc_sparsity,
                    masks=M, rows=submitters,
                )
                agg_stacks = {
                    k: np.asarray(global_params[k], np.float32)[None] * M[k]
                    + committed[k]
                    for k in P
                }
        else:
            for w in active_ws:
                if not submitters[w] or sim.dgc_sparsity <= 0.0:
                    continue
                received = extract_subparams(global_params, indices[w], env.unit_map)
                delta = {k: worker_params[w][k] - received[k] for k in worker_params[w]}
                committed_w, dgc_residuals[w], payload[w] = _dgc_compress(
                    delta, dgc_residuals[w], sim.dgc_sparsity
                )
                worker_params[w] = {k: received[k] + committed_w[k] for k in delta}

        phis = np.full(W, np.nan)
        dm = events.drift_mult
        for w in active_ws:
            pf = float(payload[w]) if submitters[w] else 1.0
            if resident:
                shapes_w = subparam_shapes(indices[w], env.unit_map, env.base_shapes)
            else:
                shapes_w = {k: v.shape for k, v in worker_params[w].items()}
            # channel retries stretch the drift factor FIRST (d*r), then the
            # jitter inside _phi_from_shapes — the fused engine associates
            # its floats the same way (j * (d * r)).
            retry_mult = 1.0
            if (ch_cfg is not None and events.retries is not None
                    and submitters[w]):
                retry_mult = (
                    1.0 + ch_cfg.retry_backoff * float(events.retries[w])
                )
            phi_w = env._phi_from_shapes(
                w, shapes_w, pf,
                time_mult=(float(dm[w]) if dm is not None else 1.0)
                * retry_mult,
            )
            phis[w] = phi_w
            interval_phis[w].append(phi_w)
            if submitters[w]:
                bytes_w = sum(int(np.prod(s)) * 4 for s in shapes_w.values())
                # lossy-channel accounting: every retry re-sends the upload,
                # a delivered duplicate arrives twice
                extra = 0.0
                if ch_cfg is not None and events.retries is not None:
                    extra = (
                        float(events.retries[w])
                        + float(events.dup[w] & events.delivered[w])
                    ) * pf * bytes_w
                comm_bytes += 2.0 * pf * bytes_w + extra
            pending_rates[w] = 0.0

        sub_phis = phis[submitters]
        round_time = float(sub_phis.max())
        if events.dropped.any() and scen is not None:
            # straggler timeout: the server waits out the deadline
            round_time *= scen.cfg.timeout_factor
        clock += round_time                     # BSP: slowest (received) gates
        upd_times.append(list(phis))
        het_traj.append((t, heterogeneity_from_times(sub_phis)))
        if W > 3:
            sim_traj.append((t, similarity(indices[1], indices[3])))

        t0 = _time.perf_counter()
        if resident:
            if agg_stacks is None:
                agg_stacks = env.fleet.params_host(state)
            if sim.aggregation == "by_unit":
                global_params = aggregate_by_unit_stacked(
                    agg_stacks, env.fleet.masks_host(state), submitters
                )
            elif robust_on:
                mult = _commit_multiplicity(events)
                global_params, rb_strikes, rb_quar, quar_now = (
                    _robust_aggregate_host(
                        agg_stacks, env.fleet.masks_host(state), global_params,
                        mult, events, byz_cfg, ch_cfg, corrupt_on, rb_cfg,
                        sim.seed, t, rb_strikes, rb_quar,
                    )
                )
                if quar_now is not None:
                    quarantined_commits += int((quar_now & (mult > 0)).sum())
            else:
                weights = submitters / submitters.sum()
                global_params = aggregate_by_worker_stacked(agg_stacks, weights)
        elif robust_on and sim.aggregation != "by_unit":
            # per-worker engines embed submissions into [W, ...] base stacks
            # and run the SAME robust pipeline; rows without a commit carry a
            # zero delta (their masked global), weight 0 and health-ineligible
            mult = _commit_multiplicity(events)
            stacks = {
                k: np.zeros((W,) + tuple(s), np.float32)
                for k, s in env.base_shapes.items()
            }
            stack_masks = {
                k: np.zeros((W,) + tuple(s), np.float32)
                for k, s in env.base_shapes.items()
            }
            for w in range(W):
                for k in stack_masks:
                    stack_masks[k][w] = coordinate_mask(
                        k, indices[w], env.unit_map, env.base_shapes
                    )
                if w in worker_params:
                    emb = embed_params(
                        worker_params[w], indices[w], env.unit_map,
                        env.base_shapes,
                    )
                    for k in stacks:
                        stacks[k][w] = emb[k]
                else:
                    for k in stacks:
                        stacks[k][w] = (
                            np.asarray(global_params[k], np.float32)
                            * stack_masks[k][w]
                        )
            global_params, rb_strikes, rb_quar, quar_now = (
                _robust_aggregate_host(
                    stacks, stack_masks, global_params, mult, events,
                    byz_cfg, ch_cfg, corrupt_on, rb_cfg,
                    sim.seed, t, rb_strikes, rb_quar,
                )
            )
            if quar_now is not None:
                quarantined_commits += int((quar_now & (mult > 0)).sum())
        else:
            submissions = [
                (worker_params[w], indices[w]) for w in active_ws if submitters[w]
            ]
            if sim.aggregation == "by_unit":
                global_params = aggregate_by_unit(
                    submissions, env.unit_map, env.base_shapes
                )
            else:
                global_params = aggregate_by_worker(
                    submissions, env.unit_map, env.base_shapes
                )
        global_params = {k: v.astype(np.float32) for k, v in global_params.items()}

        if adapt and (t % sim.prune_interval == 0 or events.drift_changed):
            _learn_rates(t, events.drift_changed)
        server_overhead += _time.perf_counter() - t0

        if t % sim.eval_every == 0:
            acc_time.append((clock, _env_accuracy(env, global_params)))

    host_roundtrips = roundtrip_total() - rt_base
    final_costs = [env.cost_for_index(indices[w]) for w in range(W)]
    return _finalize(sim, env, acc_time, het_traj, sim_traj, upd_times,
                     [retention(indices[w], env.space) for w in range(W)],
                     [extract_subparams(global_params, indices[w], env.unit_map) for w in range(W)],
                     comm_bytes, server_overhead, clock,
                     global_params=global_params, host_roundtrips=host_roundtrips,
                     scenario_rounds=scen_rows,
                     flops_per_image_final=float(np.mean([c[0] for c in final_costs])),
                     blocks_per_image_final=float(np.mean([c[2] for c in final_costs])),
                     prune_events=prune_events,
                     fault_ledger={
                         **fault_ledger(events_log),
                         "quarantined_commits": quarantined_commits,
                     })


def _scores_for(sim: SimConfig, env: _Env, worker, prune_round, params_w, index_w,
                cig_scores, state=None):
    """Importance scores in base coordinates for this worker/round.

    ``params_w`` may be None under the resident engine; the data-dependent
    criteria then extract the worker's row at this (scoring) boundary."""
    name = sim.importance
    if name == "cig_bnscalor":
        if cig_scores is None:
            raise RuntimeError("CIG order not yet frozen")
        return cig_scores
    ctx_kw = dict(unit_counts=env.space.unit_counts, worker=worker,
                  round=prune_round, seed=sim.seed)
    if name in _DATA_DEP_IMPORTANCE:
        if params_w is None:
            assert state is not None
            row = {k: np.asarray(v[worker]) for k, v in state.params.items()}
            params_w = extract_subparams(row, index_w, env.unit_map)
        x, y = env.shard_xy(worker)
        stats = local_unit_stats(env.trainer, params_w, index_w, env.space, env.unit_map, x, y)
        ctx_kw.update(weight_norms=stats["weight_norms"], grads=stats["grads"],
                      activations=stats["activations"])
    return METHODS[name](ImportanceContext(**ctx_kw))


# ---------------------------------------------------------------------------
# asynchronous methods: fedasync_s / ssp_s / dcasgd_s
# ---------------------------------------------------------------------------

def _plan_async_events(
    sim: SimConfig,
    env: _Env,
    scen: Optional[ScenarioEngine],
    participants: np.ndarray,
) -> AsyncEventPlan:
    """Pre-simulate the entire async discrete-event run (no training).

    Async workers never prune, so event timing depends only on worker
    bandwidths + jitter draws and SSP blocking only on commit counts —
    the heap loop can run to completion before any parameters exist.  This
    replays the legacy loop's exact RNG/heap order: initial ``schedule``
    per participant ascending (one jitter draw each via ``phi_from_index``;
    the per-worker path's ``env.phi(w, fetched)`` produced bit-identical
    draws because async shapes are always the base shapes), then per window
    batch: heap pops (``(time, worker)`` tuple tie-break), one
    ``make_batch_plan`` per popped row in pop order, one ``scen.rng``
    dropout draw per popped row in pop order (ONLY when dropout > 0, so
    dropout-free runs consume zero extra scenario RNG), then the per-commit
    bookkeeping walk (clock running-max, staleness before the version bump,
    SSP block/unblock with reschedule jitter draws, eval flags).

    A dropped (timed-out) commit still trains, still counts toward
    ``rounds_done``/termination, and still refetches the current global —
    but the server never merges it: no version bump, no bytes."""
    W = sim.num_workers
    method = sim.method
    idx = full_index(env.space)
    n_part = len(participants)
    drop_p = scen.cfg.dropout if scen is not None else 0.0
    # crash/recovery faults under async: one dedicated fault_rng draw per
    # popped commit in pop order (ONLY when crash is enabled, mirroring the
    # dropout stream discipline).  A crashed worker's commit still lands —
    # the crash takes it dark AFTER reporting — and its next schedule is
    # delayed by ``outage_rounds`` nominal (jitter-free) update times, so it
    # returns against a bumped server version with naturally larger
    # staleness.  No extra env.rng draws, so fault-free plans are untouched.
    crash = (
        scen.cfg.faults.crash
        if scen is not None and scen.cfg.faults is not None else None
    )
    n_crashes = 0

    fetched_ver = np.zeros(W, np.int64)
    rounds_done = np.zeros(W, np.int64)
    last_push = np.zeros(W, np.int64)
    version = 0
    push_counter = 0
    total_commits = n_part * sim.rounds
    commits = 0
    clock = 0.0
    heap: List[Tuple[float, int]] = []

    def schedule(w, now):
        nonlocal push_counter
        phi = env.phi_from_index(w, idx)
        heapq.heappush(heap, (now + phi, w))
        last_push[w] = push_counter
        push_counter += 1

    for w in participants:
        schedule(int(w), 0.0)

    workers: List[int] = []
    finishes: List[float] = []
    push_seq: List[int] = []
    staleness: List[int] = []
    versions: List[int] = []
    dropped: List[bool] = []
    refetch: List[np.ndarray] = []
    evals: List[bool] = []
    clocks: List[float] = []
    plans: List[np.ndarray] = []
    batch_starts: List[int] = [0]

    blocked: List[int] = []
    window = sim.async_window
    while commits < total_commits and heap:
        batch = [heapq.heappop(heap)]
        while (window > 0.0 and heap
               and len(batch) < total_commits - commits
               and heap[0][0] <= batch[0][0] + window):
            batch.append(heapq.heappop(heap))
        batch_plans = [
            make_batch_plan(
                len(env.shards[w]), sim.batch_size, sim.local_epochs, env.rng
            )
            for _, w in batch
        ]
        drops = (
            [bool(scen.rng.random() < drop_p) for _ in batch]
            if drop_p > 0.0 else [False] * len(batch)
        )
        crashes = (
            [bool(scen.fault_rng.random() < crash.rate) for _ in batch]
            if crash is not None else [False] * len(batch)
        )
        for (finish, w), plan, drop, crashed in zip(
            batch, batch_plans, drops, crashes
        ):
            clock = max(clock, finish)
            s = int(version - fetched_ver[w])
            if not drop:
                version += 1
            commits += 1
            rounds_done[w] += 1
            ref = np.zeros(W, bool)
            ref[w] = True
            fetched_ver[w] = version
            delay = 0.0
            if crashed:
                n_crashes += 1
                delay = crash.outage_rounds * env.phi_from_index(
                    w, idx, jitter=False
                )
            if method == "ssp_s" and rounds_done[w] >= int(
                rounds_done[participants].min()
            ) + sim.ssp_threshold:
                blocked.append(w)
            elif rounds_done[w] < sim.rounds:
                schedule(w, clock + delay)
            if method == "ssp_s" and blocked:
                min_done = int(rounds_done[participants].min())
                still = []
                for bw in blocked:
                    if (rounds_done[bw] < min_done + sim.ssp_threshold
                            and rounds_done[bw] < sim.rounds):
                        ref[bw] = True
                        fetched_ver[bw] = version
                        schedule(bw, clock)
                    else:
                        still.append(bw)
                blocked = [b for b in still if rounds_done[b] < sim.rounds]
            workers.append(int(w))
            finishes.append(float(finish))
            push_seq.append(int(last_push[w]))
            staleness.append(s)
            versions.append(version)
            dropped.append(drop)
            refetch.append(ref)
            evals.append(commits % n_part == 0)
            clocks.append(clock)
            plans.append(plan)
        batch_starts.append(commits)

    return AsyncEventPlan(
        workers=np.asarray(workers, np.int64),
        finishes=np.asarray(finishes, np.float64),
        push_seq=np.asarray(push_seq, np.int64),
        staleness=np.asarray(staleness, np.int64),
        versions=np.asarray(versions, np.int64),
        dropped=np.asarray(dropped, bool),
        refetch=(np.stack(refetch) if refetch else np.zeros((0, W), bool)),
        evals=np.asarray(evals, bool),
        clocks=np.asarray(clocks, np.float64),
        batch_starts=np.asarray(batch_starts, np.int64),
        plans=plans,
        fault_ledger=(
            dict(drift_events=0, rounds_degraded=0, rounds_skipped=0,
                 workers_recovered=n_crashes, retry_total=n_crashes)
            if crash is not None else None
        ),
    )


def _run_async(sim: SimConfig, env: _Env) -> SimResult:
    W = sim.num_workers
    lam = sim.lam
    if sim.resident_momentum:
        raise ValueError(
            "resident_momentum is a synchronous-round carry; the async "
            "schedulers restart momentum per commit like their per-worker "
            "twins"
        )

    # --- scenario: async methods honour client sampling (a static
    # C-fraction of the slot pool joins the event loop) and dropout
    # (timed-out commits in the pre-drawn event stream); churn and scripted
    # schedules stay sync-only.
    scen = ScenarioEngine(sim.scenario, W) if sim.scenario is not None else None
    if scen is not None and scen.cfg.schedule is not None:
        raise ValueError(
            "async schedulers draw their own event stream; per-round "
            "scripted schedules apply to the synchronous methods only"
        )
    if scen is not None and scen.cfg.churn > 0.0:
        raise ValueError(
            "async schedulers reject scenario churn — slot replacement "
            "resets host bookkeeping the event queue does not model; churn "
            "applies to the synchronous methods only"
        )
    if scen is not None and scen.cfg.faults is not None:
        f = scen.cfg.faults
        if f.outage is not None:
            raise ValueError(
                "async schedulers reject the outage fault family — a "
                "coordinated regional blackout is a synchronous-round "
                "concept (outage is sync-only for now); crash/recovery "
                "faults are supported under the async schedulers"
            )
        if f.drift is not None:
            raise ValueError(
                "async schedulers reject the drift fault family — "
                "capability drift exists to trigger prune-rate re-learning "
                "and async workers never prune; drift applies to the "
                "synchronous methods only"
            )
        if f.wave is not None:
            raise ValueError(
                "async schedulers reject the wave fault family — async "
                "client sampling is a static cohort drawn once at run "
                "start, not a per-round C(t); wave applies to the "
                "synchronous methods only"
            )
        if f.byzantine is not None:
            raise ValueError(
                "async schedulers reject the byzantine fault family — the "
                "compromised-cohort draw is a per-round block on the "
                "synchronous fault stream with no per-commit analogue yet "
                "(byzantine is sync-only for now)"
            )
        if f.channel is not None:
            raise ValueError(
                "async schedulers reject the channel fault family — "
                "drop/duplicate/corrupt delivery is modelled at the "
                "synchronous submission boundary, and the pre-simulated "
                "async event plan has no retry clock (channel is sync-only "
                "for now)"
            )
    rb_cfg = (
        sim.robust if sim.robust is not None and sim.robust.any_active else None
    )
    if rb_cfg is not None and rb_cfg.trim > 0.0:
        raise ValueError(
            f"RobustAggConfig.trim={rb_cfg.trim} (coordinate-wise trimmed "
            "mean) is a synchronous cohort statistic — async commits arrive "
            "one at a time with no [W, ...] stack to take order statistics "
            "over; async servers support clip + quarantine only"
        )
    participants = (
        scen.static_participants() if scen is not None else np.arange(W)
    )
    n_part = len(participants)

    # --- the whole discrete-event run, pre-simulated (commit order incl.
    # ties, staleness ints, dropout outcomes, refetch sets, clocks) — every
    # engine replays this ONE plan, so schedules are identical by
    # construction.
    plan = _plan_async_events(sim, env, scen, participants)

    if sim.engine == "fused":
        from .fused import run_async_fused   # lazy: fused imports us back

        return run_async_fused(sim, env, scen, participants, plan)

    resident = sim.engine == "masked"
    method = sim.method
    global_params = dict(env.base_params)
    idx = full_index(env.space)

    # AsyncServer.commit always rebinds a fresh params dict, so fetched
    # snapshots are safe zero-copy references on the resident path; the
    # per-worker path keeps the legacy shallow copies.
    server = AsyncServer(
        method, global_params, W, cohort_size=n_part,
        fedasync_a=sim.fedasync_a, lr=sim.lr,
        dcasgd_lambda=sim.dcasgd_lambda, dcasgd_m=sim.dcasgd_m,
        clip_norm=rb_cfg.clip if rb_cfg is not None else None,
        quarantine=rb_cfg.quarantine if rb_cfg is not None else None,
    )
    fetched = [dict(global_params) for _ in range(W)]

    state = None
    pad_steps = None
    if resident:
        shard_x, shard_y = zip(*(env.shard_xy(w) for w in range(W)))
        state = env.fleet.init_state(env.base_params, list(shard_x), list(shard_y))
        pad_steps = max(
            plan_steps(len(env.shards[w]), sim.batch_size, sim.local_epochs)
            for w in participants
        )

    comm_bytes = 0.0
    # async commits always move base-shape payloads (workers never prune)
    commit_bytes = 2.0 * sum(
        int(np.prod(s)) * 4 for s in env.base_shapes.values()
    )
    acc_time = [(0.0, _env_accuracy(env, global_params))]
    rt_base = roundtrip_total()

    for b in range(len(plan.batch_starts) - 1):
        s0, e0 = int(plan.batch_starts[b]), int(plan.batch_starts[b + 1])
        rows = [int(w) for w in plan.workers[s0:e0]]
        batch_plans = plan.plans[s0:e0]
        for p in batch_plans:  # async workers all train at the full index
            env.account_train(idx, p.shape[0])
        if resident:
            # masked scatter in: each batch worker's row becomes the global
            # snapshot it fetched at its last commit...
            env.fleet.scatter_global_rows(state, rows, [fetched[w] for w in rows])
            # ...one bucket-sized sub-stack program trains the whole batch,
            # and the trained rows come back in ONE stacked host copy.
            _, pulled = env.fleet.train_rows(
                state, rows, batch_plans, lam, pad_steps=pad_steps, to_host=True
            )
            if pulled is None:
                # no-step plans (local_epochs <= 0): commit the fetched
                # params unchanged, matching the per-worker engines
                trained_batch = [fetched[w] for w in rows]
            else:
                trained_batch = [
                    {k: v[i] for k, v in pulled.items()} for i in range(len(rows))
                ]
        else:
            jobs = []
            for w, p in zip(rows, batch_plans):
                x, y = env.shard_xy(w)
                jobs.append(FleetJob(
                    worker=w, params=fetched[w], index=idx, x=x, y=y, plan=p,
                ))
            trained_batch = env.fleet.train_all(jobs, lam)
        for i, trained in zip(range(s0, e0), trained_batch):
            w = int(plan.workers[i])
            if not plan.dropped[i]:
                global_params = server.commit(
                    w, trained, fetched[w], int(plan.staleness[i])
                )
                if not resident:
                    # per-worker path: each merged commit copies a full param
                    # dict across the host boundary — count it so
                    # host_roundtrips is honest in the baseline (SSP incl.)
                    tally_roundtrip("async_merge")
                comm_bytes += commit_bytes
            if server.version != int(plan.versions[i]):
                raise RuntimeError(
                    "async replay diverged from the pre-simulated event plan"
                )
            for rw in np.flatnonzero(plan.refetch[i]):
                fetched[int(rw)] = dict(global_params)
            if plan.evals[i]:
                acc_time.append(
                    (float(plan.clocks[i]), _env_accuracy(env, global_params))
                )

    clock = float(plan.clocks[-1]) if plan.num_events else 0.0
    host_roundtrips = roundtrip_total() - rt_base
    scen_rows = [(0, n_part, 0, 0)] if scen is not None else []
    final_cost = env.cost_for_index(idx)
    return _finalize(sim, env, acc_time, [], [], [], [1.0] * W,
                     [dict(global_params) for _ in range(W)], comm_bytes, 0.0, clock,
                     global_params=dict(global_params),
                     host_roundtrips=host_roundtrips,
                     scenario_rounds=scen_rows,
                     flops_per_image_final=final_cost[0],
                     blocks_per_image_final=final_cost[2],
                     fault_ledger={
                         **(plan.fault_ledger or {}),
                         "quarantined_commits": int(server.rejected_commits),
                     })


def _finalize(sim, env, acc_time, het_traj, sim_traj, upd_times, retentions,
              worker_params, comm_bytes, server_overhead, clock,
              global_params=None, host_roundtrips=0,
              scenario_rounds=None, flops_per_image_final=0.0,
              blocks_per_image_final=0.0, prune_events=None,
              fused_chunks=0, fault_ledger=None) -> SimResult:
    accs = np.array([a for _, a in acc_time])
    times = np.array([t for t, _ in acc_time])
    best = int(np.argmax(accs))
    param_sizes = [sum(v.size for v in p.values()) for p in worker_params]
    flops = [cnn_flops(p, sim.cnn) for p in worker_params]
    full_size = sum(v.size for v in env.base_params.values())
    if sim.mesh is not None:
        n_devices = int(np.prod(list(sim.mesh.shape.values())))
        fleet_axis_size = int(sim.mesh.shape[sim.fleet_axis])
        shard_spec = f"PartitionSpec({sim.fleet_axis!r})"
    else:
        n_devices, fleet_axis_size, shard_spec = 1, 1, None
    return SimResult(
        method=sim.method,
        acc_time=acc_time,
        final_acc=float(accs[-1]),
        best_acc=float(accs[best]),
        best_acc_time=float(times[best]),
        total_time=float(clock),
        het_traj=het_traj,
        retentions=retentions,
        param_reduction=1.0 - float(np.mean(param_sizes)) / full_size,
        flops_reduction=1.0 - float(np.mean(flops)) / env.full_flops,
        comm_bytes=comm_bytes,
        server_overhead_s=server_overhead,
        recompiles=env.trainer.compile_count,
        similarity_traj=sim_traj,
        update_times=upd_times,
        engine=sim.engine,
        batched_calls=env.fleet.batched_calls,
        host_roundtrips=host_roundtrips,
        host_dispatches=env.trainer.dispatch_count,
        compile_walltime_s=env.trainer.compile_walltime_s,
        fused_chunks=fused_chunks,
        n_devices=n_devices,
        fleet_axis_size=fleet_axis_size,
        shard_spec=shard_spec,
        prune_events=prune_events or [],
        scenario_rounds=scenario_rounds or [],
        **(fault_ledger or {}),
        bucket_sizes=sorted(env.fleet.buckets_used),
        compute=sim.compute,
        flops_executed=env.flops_executed,
        flops_ideal=env.flops_ideal,
        blocks_executed=env.blocks_executed,
        flops_per_image_final=flops_per_image_final,
        blocks_per_image_final=blocks_per_image_final,
        global_params={k: np.asarray(v) for k, v in global_params.items()}
        if global_params is not None else None,
    )


def run_simulation(sim: SimConfig) -> SimResult:
    t0 = _time.perf_counter()
    env = _Env(sim)
    if sim.method in ("adaptcl", "fedavg", "fedavg_s"):
        if sim.engine == "fused":
            from .fused import run_sync_fused   # lazy: fused imports us back

            result = run_sync_fused(sim, env)
        else:
            result = _run_sync(sim, env)
    elif sim.method in ("fedasync_s", "ssp_s", "dcasgd_s"):
        result = _run_async(sim, env)   # routes engine == "fused" itself
    else:
        raise ValueError(f"unknown method {sim.method}")
    result.walltime_s = _time.perf_counter() - t0
    return result
