"""Multi-worker collaborative-learning simulator (AdaptCL §IV).

Faithful-reproduction engine: W workers with heterogeneous bandwidths (Eq. 6/7
channel model), a virtual clock, and six frameworks:

  * ``adaptcl``    — Algorithm 1 (+ Algorithm 2 pruned-rate learning)
  * ``fedavg``     — McMahan et al. BSP
  * ``fedavg_s``   — + group-lasso sparse training (the paper's main baseline)
  * ``fedasync_s`` — Xie et al. async with polynomial staleness weighting
  * ``ssp_s``      — stale-synchronous parallel (threshold s)
  * ``dcasgd_s``   — DC-ASGD-a (delay-compensated async gradients)

All methods share the same bandwidth assignment, data partition, and model
init, as in the paper.  Update times are simulated through the channel model
(training-time sensitivity to pruning is configurable, Appendix E); virtual
time is what produces the paper's Time columns.

Local training is dispatched through the **fleet engine** (``core.fleet``),
selected by ``SimConfig.engine``:

  * ``"sequential"`` — one scan-train call per worker (reference engine);
  * ``"bucketed"``   — workers sharing a parameter-shape signature are
    stacked and trained in one jitted ``vmap`` call;
  * ``"masked"``     — all workers stay at base shape behind 0/1 unit masks
    (the ``kernels/pruned_matmul`` idiom), so the whole fleet batches into a
    single program and pruning causes zero reconfigure-recompiles.

Minibatch plans are pre-drawn per worker in a fixed order, so all three
engines consume identical batch sequences and produce numerically equivalent
trained models (``tests/test_fleet_equivalence.py``).  ``SimResult`` reports
``recompiles`` (jit shape-signatures compiled), ``batched_calls`` (device
programs launched by the batched engines), and ``walltime_s`` (host
wall-clock) so the engines' host-cost can be compared directly.
"""
from __future__ import annotations

import dataclasses
import heapq
import time as _time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import SyntheticImageTask, batch_iterator, partition_noniid
from repro.models.cnn import (
    CNNConfig,
    build_unit_space,
    cnn_apply,
    cnn_flops,
    extract_bn_scales,
    init_cnn,
    vgg_config,
)

from .aggregation import aggregate_by_unit, aggregate_by_worker, extract_subparams
from .fleet import FleetEngine, FleetJob
from .importance import CIG_METHODS, METHODS, ImportanceContext
from .masks import full_index, is_nested, payload_bytes, retention, similarity
from .pruned_rate import PrunedRateConfig, WorkerHistory, learn_pruned_rates
from .timing import HeterogeneityConfig, heterogeneity_from_times, make_bandwidths
from .worker import LocalTrainer, local_unit_stats, make_batch_plan

__all__ = ["SimConfig", "SimResult", "run_simulation", "default_cnn"]


def default_cnn() -> CNNConfig:
    """Small VGG used by the CPU-budget simulations (same family as VGG16)."""
    return vgg_config("vgg_sim", [32, "M", 64, "M", 64], num_classes=10, image_size=16)


@dataclasses.dataclass
class SimConfig:
    method: str = "adaptcl"
    rounds: int = 30
    num_workers: int = 10
    local_epochs: float = 1.0
    batch_size: int = 32
    lr: float = 0.05
    lam: float = 1e-4                   # group-lasso coefficient (sparse train)
    prune_interval: int = 5             # PI (paper: 10, T=150; scaled T=30)
    beta: float = 1.0                   # pruning position within local epochs
    importance: str = "cig_bnscalor"
    aggregation: str = "by_worker"
    rate_cfg: PrunedRateConfig = dataclasses.field(default_factory=PrunedRateConfig)
    het: HeterogeneityConfig = dataclasses.field(default_factory=HeterogeneityConfig)
    t_train_full: float = 1.0           # seconds per local round, full model
    train_sens: float = 0.1             # Appendix E: GPU-like ~0, CPU-like ~1
    time_jitter: float = 0.02
    noniid_s: float = 0.0               # paper's s%: 0 (IID) or 80
    ssp_threshold: int = 2
    fedasync_a: float = 0.5
    dcasgd_lambda: float = 2.0
    dcasgd_m: float = 0.95
    fixed_pruned_rates: Optional[List[List[float]]] = None  # Tab. IX mode
    # AdaptCL+DGC (Appendix E / Tab. XVII): commit only the largest
    # (1-sparsity) fraction of each weight delta; the rest accumulates
    # locally until it crosses the threshold (momentum-factor-masking lite).
    dgc_sparsity: float = 0.0
    # local-training engine: "sequential" | "bucketed" | "masked" (core.fleet)
    engine: str = "sequential"
    cnn: CNNConfig = dataclasses.field(default_factory=default_cnn)
    task: Optional[SyntheticImageTask] = None
    eval_every: int = 1
    seed: int = 0


@dataclasses.dataclass
class SimResult:
    method: str
    acc_time: List[Tuple[float, float]]         # (virtual seconds, test acc)
    final_acc: float
    best_acc: float
    best_acc_time: float
    total_time: float
    het_traj: List[Tuple[int, float]]            # (round, H of update times)
    retentions: List[float]                      # final gamma per worker
    param_reduction: float                       # avg over workers
    flops_reduction: float
    comm_bytes: float
    server_overhead_s: float                     # Alg.2 + aggregation walltime
    recompiles: int
    similarity_traj: List[Tuple[int, float]]     # Eq. 3 between two workers
    update_times: List[List[float]]              # per round, per worker
    engine: str = "sequential"                   # fleet engine that ran it
    batched_calls: int = 0                       # vmapped device programs
    walltime_s: float = 0.0                      # host wall-clock of the run


def _accuracy(params, cfg, x, y, batch=256) -> float:
    correct = 0
    for i in range(0, len(x), batch):
        logits = cnn_apply({k: jnp.asarray(v) for k, v in params.items()}, cfg, jnp.asarray(x[i : i + batch]))
        correct += int((np.argmax(np.asarray(logits), -1) == y[i : i + batch]).sum())
    return correct / len(x)


class _Env:
    """Shared experimental fixture (same across all methods, per seed)."""

    def __init__(self, sim: SimConfig):
        self.sim = sim
        self.task = sim.task or SyntheticImageTask(
            num_classes=sim.cnn.num_classes, image_size=sim.cnn.image_size,
            train_size=1280, test_size=512, seed=sim.seed,
        )
        self.shards = partition_noniid(
            self.task.y_train, sim.num_workers, sim.noniid_s, seed=sim.seed
        )
        key = jax.random.PRNGKey(sim.seed)
        self.base_params = {k: np.asarray(v) for k, v in init_cnn(key, sim.cnn).items()}
        self.base_shapes = {k: v.shape for k, v in self.base_params.items()}
        self.space, self.unit_map = build_unit_space(sim.cnn, self.base_params)
        self.full_bytes = payload_bytes(full_index(self.space), self.space)
        self.full_flops = cnn_flops(self.base_params, sim.cnn)
        self.bandwidths = make_bandwidths(sim.het, self.full_bytes, sim.t_train_full)
        self.trainer = LocalTrainer(sim.cnn, lr=sim.lr)
        self.fleet = FleetEngine(
            self.trainer, self.unit_map, self.base_shapes, engine=sim.engine
        )
        self.rng = np.random.default_rng(sim.seed + 17)

    def phi(self, worker: int, params, payload_factor: float = 1.0) -> float:
        """Channel-model update time for this worker's current sub-model."""
        sim = self.sim
        bytes_w = payload_factor * sum(v.size * 4 for v in params.values())
        flops_w = cnn_flops(params, sim.cnn)
        rel = flops_w / self.full_flops
        t_train = sim.t_train_full * ((1 - sim.train_sens) + sim.train_sens * rel)
        t = 2.0 * bytes_w / self.bandwidths[worker] + t_train * sim.local_epochs
        if sim.time_jitter > 0:
            t *= float(np.exp(self.rng.normal(0, sim.time_jitter)))
        return t

    def shard_xy(self, w):
        sh = self.shards[w]
        return self.task.x_train[sh], self.task.y_train[sh]


# ---------------------------------------------------------------------------
# synchronous methods: fedavg / fedavg_s / adaptcl
# ---------------------------------------------------------------------------

def _dgc_compress(delta: Dict[str, np.ndarray], residual: Dict[str, np.ndarray],
                  sparsity: float):
    """Top-|.| delta sparsification with local residual accumulation ([11]).

    Returns (committed delta, new residual, kept-fraction payload factor)."""
    committed, new_res = {}, {}
    kept = total = 0
    for k, d in delta.items():
        r = residual.get(k)
        if r is not None and r.shape == d.shape:
            d = d + r
        # (a reconfiguration changed this tensor's shape -> residual dropped;
        # DGC's accumulators are restarted after each pruning, like momentum)
        flat = np.abs(d).ravel()
        n_keep = max(1, int(round(flat.size * (1.0 - sparsity))))
        if n_keep >= flat.size:
            committed[k], new_res[k] = d, np.zeros_like(d)
        else:
            thr = np.partition(flat, flat.size - n_keep)[flat.size - n_keep]
            mask = np.abs(d) >= thr
            committed[k] = d * mask
            new_res[k] = d * (1.0 - mask)
        kept += n_keep
        total += flat.size
    # payload: kept values + their indices (~1.25x values, as in DGC)
    return committed, new_res, 1.25 * kept / max(total, 1)


def _run_sync(sim: SimConfig, env: _Env) -> SimResult:
    W = sim.num_workers
    sparse = sim.method in ("fedavg_s", "adaptcl")
    adapt = sim.method == "adaptcl"
    lam = sim.lam if sparse else 0.0
    dgc_residuals: List[Dict[str, np.ndarray]] = [{} for _ in range(W)]

    global_params = dict(env.base_params)
    indices = [full_index(env.space) for _ in range(W)]
    histories = [WorkerHistory() for _ in range(W)]
    pending_rates = [0.0] * W
    cig_scores = None              # frozen at first pruning (CIG principle)
    interval_phis: List[List[float]] = [[] for _ in range(W)]
    prune_round_count = 0

    clock = 0.0
    comm_bytes = 0.0
    server_overhead = 0.0
    acc_time, het_traj, sim_traj, upd_times = [], [], [], []
    acc0 = _accuracy(global_params, sim.cnn, env.task.x_test, env.task.y_test)
    acc_time.append((0.0, acc0))

    for t in range(1, sim.rounds + 1):
        submissions = []
        phis = []
        # --- phase A: every worker's pre-prune local training, one fleet
        # call.  Batch plans are drawn in worker order up front so the batch
        # sequences (and therefore the trained models) are identical across
        # engines.
        jobs_a: List[FleetJob] = []
        plans_b: List[np.ndarray] = []
        for w in range(W):
            # server sends theta_g ⊙ I_w  (Alg. 1 line 9)
            params_w = extract_subparams(global_params, indices[w], env.unit_map)
            x, y = env.shard_xy(w)
            rate = pending_rates[w] if adapt else 0.0
            if adapt and rate > 0.0:
                e1, e2 = sim.beta * sim.local_epochs, (1 - sim.beta) * sim.local_epochs
            else:
                e1, e2 = sim.local_epochs, 0.0
            jobs_a.append(FleetJob(
                worker=w, params=params_w, index=indices[w], x=x, y=y,
                plan=make_batch_plan(len(x), sim.batch_size, e1, env.rng),
            ))
            plans_b.append(make_batch_plan(len(x), sim.batch_size, e2, env.rng))
        trained_a = env.fleet.train_all(jobs_a, lam)

        # --- phase B: pruning workers prune/reconfigure at position beta,
        # then finish their remaining epochs (second fleet call).
        worker_params: List[Dict[str, np.ndarray]] = list(trained_a)
        jobs_b: List[FleetJob] = []
        for w in range(W):
            rate = pending_rates[w] if adapt else 0.0
            if adapt and rate > 0.0:
                scores = _scores_for(sim, env, w, prune_round_count,
                                     worker_params[w], indices[w], cig_scores)
                worker_params[w], indices[w] = env.trainer.prune_and_reconfigure(
                    worker_params[w], indices[w], scores, rate, env.space, env.unit_map
                )
                if plans_b[w].shape[0] > 0:
                    x, y = env.shard_xy(w)
                    jobs_b.append(FleetJob(
                        worker=w, params=worker_params[w], index=indices[w],
                        x=x, y=y, plan=plans_b[w],
                    ))
        if jobs_b:
            for job, trained in zip(jobs_b, env.fleet.train_all(jobs_b, lam)):
                worker_params[job.worker] = trained

        # --- submission: channel model + (optional) DGC delta compression.
        for w in range(W):
            params_w = worker_params[w]
            payload_factor = 1.0
            if sim.dgc_sparsity > 0.0:
                received = extract_subparams(global_params, indices[w], env.unit_map)
                delta = {k: params_w[k] - received[k] for k in params_w}
                committed, dgc_residuals[w], payload_factor = _dgc_compress(
                    delta, dgc_residuals[w], sim.dgc_sparsity
                )
                params_w = {k: received[k] + committed[k] for k in params_w}
            phi_w = env.phi(w, params_w, payload_factor)
            phis.append(phi_w)
            interval_phis[w].append(phi_w)
            comm_bytes += 2.0 * payload_factor * sum(v.size * 4 for v in params_w.values())
            submissions.append((params_w, indices[w]))
        pending_rates = [0.0] * W

        clock += max(phis)                      # BSP: slowest worker gates
        upd_times.append(phis)
        het_traj.append((t, heterogeneity_from_times(phis)))
        sim_traj.append((t, similarity(indices[1], indices[3])))

        t0 = _time.perf_counter()
        if sim.aggregation == "by_unit":
            global_params = aggregate_by_unit(submissions, env.unit_map, env.base_shapes)
        else:
            global_params = aggregate_by_worker(submissions, env.unit_map, env.base_shapes)
        global_params = {k: v.astype(np.float32) for k, v in global_params.items()}

        if adapt and t % sim.prune_interval == 0:
            prune_round_count += 1
            if cig_scores is None and sim.importance == "cig_bnscalor":
                cig_scores = METHODS["cig_bnscalor"](ImportanceContext(
                    unit_counts=env.space.unit_counts,
                    scales=extract_bn_scales(global_params, sim.cnn),
                ))
            gammas_now = [retention(indices[w], env.space) for w in range(W)]
            phis_now = [float(np.mean(interval_phis[w])) for w in range(W)]
            for w in range(W):
                histories[w].record(gammas_now[w], phis_now[w])
            if sim.fixed_pruned_rates is not None:
                k = prune_round_count - 1
                rates = (
                    sim.fixed_pruned_rates[k]
                    if k < len(sim.fixed_pruned_rates)
                    else [0.0] * W
                )
            else:
                rates = learn_pruned_rates(histories, gammas_now, phis_now, sim.rate_cfg)
            pending_rates = list(rates)
            interval_phis = [[] for _ in range(W)]
        server_overhead += _time.perf_counter() - t0

        if t % sim.eval_every == 0:
            acc_time.append((clock, _accuracy(global_params, sim.cnn, env.task.x_test, env.task.y_test)))

    return _finalize(sim, env, acc_time, het_traj, sim_traj, upd_times,
                     [retention(indices[w], env.space) for w in range(W)],
                     [extract_subparams(global_params, indices[w], env.unit_map) for w in range(W)],
                     comm_bytes, server_overhead, clock)


def _scores_for(sim: SimConfig, env: _Env, worker, prune_round, params_w, index_w, cig_scores):
    """Importance scores in base coordinates for this worker/round."""
    name = sim.importance
    if name == "cig_bnscalor":
        if cig_scores is None:
            raise RuntimeError("CIG order not yet frozen")
        return cig_scores
    ctx_kw = dict(unit_counts=env.space.unit_counts, worker=worker,
                  round=prune_round, seed=sim.seed)
    if name in ("l1", "taylor", "fpgm", "hrank"):
        x, y = env.shard_xy(worker)
        stats = local_unit_stats(env.trainer, params_w, index_w, env.space, env.unit_map, x, y)
        ctx_kw.update(weight_norms=stats["weight_norms"], grads=stats["grads"],
                      activations=stats["activations"])
    return METHODS[name](ImportanceContext(**ctx_kw))


# ---------------------------------------------------------------------------
# asynchronous methods: fedasync_s / ssp_s / dcasgd_s
# ---------------------------------------------------------------------------

def _run_async(sim: SimConfig, env: _Env) -> SimResult:
    W = sim.num_workers
    lam = sim.lam
    method = sim.method
    global_params = dict(env.base_params)
    version = 0
    idx = full_index(env.space)

    # per-worker: fetched params, fetched version, local round counter
    fetched = [dict(global_params) for _ in range(W)]
    fetched_ver = [0] * W
    rounds_done = [0] * W
    backup = [dict(global_params) for _ in range(W)]        # DC-ASGD w_bak
    dc_m = {k: np.zeros_like(v) for k, v in global_params.items()}

    total_commits = W * sim.rounds
    commits = 0
    clock = 0.0
    comm_bytes = 0.0
    acc_time = [(0.0, _accuracy(global_params, sim.cnn, env.task.x_test, env.task.y_test))]
    heap: List[Tuple[float, int]] = []

    def schedule(w, now):
        phi = env.phi(w, fetched[w])
        heapq.heappush(heap, (now + phi, w))

    for w in range(W):
        schedule(w, 0.0)

    blocked: List[int] = []
    while commits < total_commits and heap:
        finish, w = heapq.heappop(heap)
        clock = max(clock, finish)
        x, y = env.shard_xy(w)
        # async commits are one-at-a-time by construction, but they still pull
        # trained results from the fleet so all engines share one train path
        # (masked/bucketed amortize to a single jitted program here too).
        [trained] = env.fleet.train_all([FleetJob(
            worker=w, params=fetched[w], index=idx, x=x, y=y,
            plan=make_batch_plan(len(x), sim.batch_size, sim.local_epochs, env.rng),
        )], lam)
        staleness = version - fetched_ver[w]
        if method == "fedasync_s":
            a = sim.fedasync_a * (staleness + 1.0) ** -0.5
            global_params = {
                k: (1 - a) * global_params[k] + a * trained[k] for k in global_params
            }
        elif method == "ssp_s":
            delta = {k: trained[k] - fetched[w][k] for k in trained}
            global_params = {k: global_params[k] + delta[k] / W for k in global_params}
        elif method == "dcasgd_s":
            # committed "gradient" = accumulated local update / lr
            g = {k: (fetched[w][k] - trained[k]) / sim.lr for k in trained}
            for k in g:
                dc_m[k] = sim.dcasgd_m * dc_m[k] + (1 - sim.dcasgd_m) * g[k] * g[k]
                lam_t = sim.dcasgd_lambda / np.sqrt(np.mean(dc_m[k]) + 1e-12)
                comp = g[k] + lam_t * g[k] * g[k] * (global_params[k] - backup[w][k])
                global_params[k] = global_params[k] - sim.lr * comp
            backup[w] = dict(global_params)
        version += 1
        commits += 1
        rounds_done[w] += 1
        comm_bytes += 2.0 * sum(v.size * 4 for v in trained.values())
        # refetch + maybe block (SSP)
        fetched[w] = dict(global_params)
        fetched_ver[w] = version
        if method == "ssp_s" and rounds_done[w] >= min(rounds_done) + sim.ssp_threshold:
            blocked.append(w)
        elif rounds_done[w] < sim.rounds:
            schedule(w, clock)
        if method == "ssp_s" and blocked:
            still = []
            for bw in blocked:
                if rounds_done[bw] < min(rounds_done) + sim.ssp_threshold and rounds_done[bw] < sim.rounds:
                    fetched[bw] = dict(global_params)
                    fetched_ver[bw] = version
                    schedule(bw, clock)
                else:
                    still.append(bw)
            blocked = [b for b in still if rounds_done[b] < sim.rounds]
        if commits % W == 0:
            acc_time.append((clock, _accuracy(global_params, sim.cnn, env.task.x_test, env.task.y_test)))

    return _finalize(sim, env, acc_time, [], [], [], [1.0] * W,
                     [dict(global_params) for _ in range(W)], comm_bytes, 0.0, clock)


def _finalize(sim, env, acc_time, het_traj, sim_traj, upd_times, retentions,
              worker_params, comm_bytes, server_overhead, clock) -> SimResult:
    accs = np.array([a for _, a in acc_time])
    times = np.array([t for t, _ in acc_time])
    best = int(np.argmax(accs))
    param_sizes = [sum(v.size for v in p.values()) for p in worker_params]
    flops = [cnn_flops(p, sim.cnn) for p in worker_params]
    full_size = sum(v.size for v in env.base_params.values())
    return SimResult(
        method=sim.method,
        acc_time=acc_time,
        final_acc=float(accs[-1]),
        best_acc=float(accs[best]),
        best_acc_time=float(times[best]),
        total_time=float(clock),
        het_traj=het_traj,
        retentions=retentions,
        param_reduction=1.0 - float(np.mean(param_sizes)) / full_size,
        flops_reduction=1.0 - float(np.mean(flops)) / env.full_flops,
        comm_bytes=comm_bytes,
        server_overhead_s=server_overhead,
        recompiles=env.trainer.compile_count,
        similarity_traj=sim_traj,
        update_times=upd_times,
        engine=sim.engine,
        batched_calls=env.fleet.batched_calls,
    )


def run_simulation(sim: SimConfig) -> SimResult:
    t0 = _time.perf_counter()
    env = _Env(sim)
    if sim.method in ("adaptcl", "fedavg", "fedavg_s"):
        result = _run_sync(sim, env)
    elif sim.method in ("fedasync_s", "ssp_s", "dcasgd_s"):
        result = _run_async(sim, env)
    else:
        raise ValueError(f"unknown method {sim.method}")
    result.walltime_s = _time.perf_counter() - t0
    return result
