"""Fleet engine: shape-bucketed / masked vmapped local training (DESIGN §IV).

The simulator's cost model is the paper's; its *host* cost used to be W
sequential ``LocalTrainer.train`` calls per round, with one fresh jit per
distinct pruned shape — wall-clock linear in workers, recompiles linear in
pruning diversity.  This module batches the fleet:

* ``sequential`` — reference engine: one scan-train call per worker, in
  worker order.  Numerically the baseline the other engines are tested
  against.
* ``bucketed``   — workers whose sub-models share a parameter-shape
  signature (and shard/plan shapes) are stacked and trained in ONE jitted
  ``vmap``-of-``scan`` program (stacked params, stacked shards, stacked
  optimizer state, per-worker batch plans).  W homogeneous workers → one
  compile, one device program.
* ``masked``     — every worker stays at BASE shape; its sub-model is a 0/1
  coordinate mask (same masking idiom as ``kernels/pruned_matmul``: prune =
  multiply by zero, never reshape), so *all* workers bucket together and
  pruning events trigger **zero** reconfigure-recompiles (compiles happen
  only per distinct fleet-stack shape — e.g. a different number of phase-B
  pruners — never because a sub-model changed shape).  Masked training
  is numerically equivalent to reconfigured training for the CNN family
  here: a fully-masked filter produces exactly-zero activations, BN of an
  all-zero channel is ``(0)*rsqrt(eps)*0+0 = 0``, and masked-loss gradients
  vanish on pruned coordinates, so retained coordinates see the same
  function as the physically-small model.

The masked engine's *device* cost is set by the trainer's ``compute`` path:
``"dense"`` executes base-shape convs (masks are 0/1 multiplies, so pruning
saves recompiles and round-trips but zero FLOPs), while ``"block_skip"``
dispatches the convs + head through ``kernels.pruned_matmul`` — the vmapped
resident program then carries per-row block-keep flags, one fleet program
serves heterogeneous retentions, and fully-pruned mask blocks execute zero
MXU passes (device FLOPs finally track retention, the paper's speedup
story).  ``FleetEngine.compute`` surfaces which path is live.

On top of the masked idiom sits the **resident fleet state** (``FleetState``):
stacked ``[W, ...]`` base-shape param / mask / momentum arrays that live on
device across rounds.  Sub-model identity is carried ONLY by the 0/1 mask
stack — the synchronous simulator never calls ``extract_subparams`` /
``embed_params`` inside its round loop (assertable via
``aggregation.ROUNDTRIP_COUNTS``):

* ``scatter_global``  — broadcast-back is a masked scatter,
  ``P = theta_g[None] * M``;
* ``train_rounds``    — one jitted vmap-of-scan over the whole stack, with a
  per-step validity mask so ragged plans and per-round participation
  (scenario sampling / dropout) never change device shapes: the one-compile
  guarantee survives hundreds of partially-participating workers;
* ``refresh_masks``   — a pruning event only rewrites mask rows (and
  re-masks the param stack); shapes never change, so zero recompiles;
* aggregation consumes the stacks directly
  (``aggregation.aggregate_by_worker_stacked`` / ``_by_unit_stacked``).

**Participation-sized compute** (``train_rows``): when only a subset of the
slots has work this phase — scenario sampling at C < 1, straggler dropout,
or an async window batch — the active rows are gathered into a fixed-size
``[B, ...]`` sub-stack before the vmapped scan, so device FLOPs track
participation instead of W.  ``B`` is padded up to the next power of two
(capped at W, padding rows are fully step-invalid) so the whole run touches
only a logarithmic set of device shapes: recompiles are bounded by the
number of distinct sub-stack bucket sizes (``buckets_used``), and the step
dimension is padded to a per-phase constant (``worker.plan_steps`` over all
slots) so ragged subsets never add shapes of their own.  Trained rows are
scattered back into the resident stacks; the async schedulers additionally
pull the ``[B, ...]`` trained rows to host in ONE copy per fleet call (the
"stacked aggregate out" their per-commit merges consume).

Every engine consumes identical pre-drawn batch plans (``make_batch_plan``),
which is what the equivalence tests pin down.  Compiles are counted in the
underlying ``LocalTrainer.compile_count`` and surfaced as
``SimResult.recompiles``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.group_lasso import group_size_sqrt, group_size_sqrt_from_shapes

from .aggregation import (
    UnitMap,
    coordinate_mask,
    embed_params,
    extract_subparams,
    subparam_shapes,
)
from .masks import GlobalIndex, UnitFlat
from .worker import LocalTrainer, Params, stack_batch_plans

__all__ = [
    "ENGINES",
    "FleetJob",
    "FleetEngine",
    "FleetState",
    "bucket_rows",
    "global_to_shard_local",
    "gather_stack_rows",
    "scatter_stack_rows",
    "refetch_rows_jnp",
    "masks_from_presence",
    "gl_factors_from_counts",
]

# "fused" shares the masked engine's resident representation; its round loop
# additionally runs as chunked on-device lax.scan programs (core.fused)
ENGINES = ("sequential", "bucketed", "masked", "fused")


def masks_from_presence(
    presence: jnp.ndarray,                     # [W, U] flat 0/1
    flat: UnitFlat,
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> Dict[str, jnp.ndarray]:
    """Device rebuild of the ``[W, ...]`` 0/1 mask stacks from a flat
    presence matrix — the in-scan analogue of ``FleetEngine.refresh_masks``
    (same product-over-governed-axes construction, pure ``jnp``)."""
    W = presence.shape[0]
    rows = {
        name: presence[:, flat.offsets[l] : flat.offsets[l] + flat.sizes[l]]
        for l, name in enumerate(flat.names)
    }
    masks: Dict[str, jnp.ndarray] = {}
    for path, shape in base_shapes.items():
        m = jnp.ones((W,) + tuple(shape), jnp.float32)
        for lname, axis in unit_map.get(path, ()):
            bshape = [W] + [1] * len(shape)
            bshape[1 + axis] = shape[axis]
            m = m * rows[lname].reshape(bshape)
        masks[path] = m
    return masks


def gl_factors_from_counts(
    counts: Mapping[str, jnp.ndarray],         # {lname: [W] retained counts}
    unit_map: UnitMap,
    base_shapes: Mapping[str, tuple],
) -> Dict[str, jnp.ndarray]:
    """Device analogue of ``group_size_sqrt_from_shapes``: per-worker
    sqrt-group-size factors from retained-unit counts alone.  A path's
    reconfigured numel is its static numel with every governed axis rescaled
    by ``count/base``; a unit layer's group size is the sum over the paths it
    governs of ``numel / count``."""
    numel: Dict[str, jnp.ndarray] = {}
    for path, shape in base_shapes.items():
        val = jnp.asarray(float(np.prod(shape)), jnp.float32)
        for lname, axis in unit_map.get(path, ()):
            val = val / float(shape[axis]) * counts[lname]
        numel[path] = val
    sizes: Dict[str, jnp.ndarray] = {}
    for path, entries in unit_map.items():
        if path not in base_shapes:
            continue
        for lname, axis in entries:
            contrib = numel[path] / jnp.maximum(counts[lname], 1.0)
            sizes[lname] = sizes.get(lname, 0.0) + contrib
    return {lname: jnp.sqrt(v) for lname, v in sizes.items()}


def bucket_rows(n: int, cap: int, multiple: int = 1) -> int:
    """Sub-stack row bucket for ``n`` active rows: the smallest power of two
    >= n, capped at the fleet size.  A handful of buckets covers every
    participation pattern, which is what bounds recompiles.

    ``multiple`` (the shard count of a mesh-sharded fleet) floors the bucket:
    a gathered sub-stack must itself divide across the fleet axis, so buckets
    below the shard count round up to it (pow2 buckets >= a pow2 shard count
    already divide; a sharded fleet's shard count is a device count, i.e.
    pow2 on every mesh we build)."""
    if n < 1:
        raise ValueError(f"bucket_rows needs n >= 1, got {n}")
    if multiple < 1:
        raise ValueError(f"bucket_rows needs multiple >= 1, got {multiple}")
    b = 1
    while b < n:
        b <<= 1
    b = min(b, cap)
    if b % multiple:
        b = min(-(-b // multiple) * multiple, cap)
        if b % multiple:
            raise ValueError(
                f"fleet size {cap} does not divide over {multiple} shards"
            )
    return b


def global_to_shard_local(
    rows: Sequence[int], num_workers: int, num_shards: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Map GLOBAL worker slot ids to ``(shard, local_row)`` pairs under the
    contiguous row layout of a fleet sharded over a mesh axis: slot ``w``
    lives on shard ``w // W_local`` at local row ``w % W_local`` with
    ``W_local = W / num_shards``.  This is the index algebra behind the
    sampled-cohort gather on a sharded fleet — per-shard work is
    ``gather(local_rows[shard_ids == s])``, never a raw global ``take`` on a
    per-shard array (which would silently clamp out-of-shard rows).

    Out-of-range slot ids and non-divisible fleets raise instead of
    wrapping."""
    if num_shards < 1 or num_workers % num_shards:
        raise ValueError(
            f"fleet of {num_workers} does not divide over {num_shards} shards"
        )
    rows = np.asarray(rows, np.int64)
    if rows.size and (rows.min() < 0 or rows.max() >= num_workers):
        raise ValueError(
            f"slot ids {rows[(rows < 0) | (rows >= num_workers)]} outside "
            f"[0, {num_workers})"
        )
    w_local = num_workers // num_shards
    return rows // w_local, rows % w_local


def _check_rows(rows: np.ndarray, num_rows: Optional[int]) -> np.ndarray:
    rows = np.asarray(rows, np.int64)
    if num_rows is not None and rows.size and (
        rows.min() < 0 or rows.max() >= num_rows
    ):
        raise ValueError(
            f"row ids {rows[(rows < 0) | (rows >= num_rows)]} outside "
            f"[0, {num_rows}) — pass GLOBAL slot ids (use "
            "global_to_shard_local for per-shard layouts)"
        )
    return rows


def gather_stack_rows(
    stacks: Mapping[str, jnp.ndarray],
    rows: np.ndarray,
    num_rows: Optional[int] = None,
) -> Dict[str, jnp.ndarray]:
    """Gather rows of ``[W, ...]`` stacks into a ``[B, ...]`` sub-stack
    (``rows`` may repeat indices — bucket padding repeats row 0).

    ``rows`` are GLOBAL slot ids; pass ``num_rows=W`` to assert that (the
    device ``take`` clamps silently, so an out-of-range id would otherwise
    mis-gather).  On a mesh-sharded stack the gather is a cross-shard
    collective compiled by GSPMD — correct for any row mix."""
    idx = jnp.asarray(_check_rows(rows, num_rows))
    return {k: jnp.take(v, idx, axis=0) for k, v in stacks.items()}


def scatter_stack_rows(
    stacks: Mapping[str, jnp.ndarray],
    rows: np.ndarray,
    sub: Mapping[str, jnp.ndarray],
    num_rows: Optional[int] = None,
) -> Dict[str, jnp.ndarray]:
    """Scatter the first ``len(rows)`` rows of a sub-stack back into the
    ``[W, ...]`` stacks (the inverse of ``gather_stack_rows`` on real rows;
    bucket-padding rows beyond ``len(rows)`` are discarded).  ``rows`` are
    GLOBAL slot ids, bounds-checked like the gather."""
    idx = jnp.asarray(_check_rows(rows, num_rows))
    n = len(rows)
    return {k: v.at[idx].set(sub[k][:n]) for k, v in stacks.items()}


def refetch_rows_jnp(
    fetched: Mapping[str, jnp.ndarray],   # {path: [W, ...]} fetched snapshots
    refetch_mask: jnp.ndarray,            # [W] 0/1: rows refetching the global
    global_p: Mapping[str, jnp.ndarray],  # {path: [...]} current global
) -> Dict[str, jnp.ndarray]:
    """Masked refetch: rows flagged in ``refetch_mask`` take the current
    global, the rest keep their snapshot — the fused async engine's in-scan
    twin of ``fetched[w] = dict(global_params)`` (``refetch_mask`` is traced,
    so SSP's data-dependent unblock refetches stay inside the scan)."""
    return {
        k: jnp.where(
            refetch_mask.reshape((-1,) + (1,) * (v.ndim - 1)) > 0,
            global_p[k][None],
            v,
        )
        for k, v in fetched.items()
    }


@dataclasses.dataclass
class FleetJob:
    """One worker's local-training work item for a round phase."""

    worker: int
    params: Params            # reconfigured (physically small) sub-model
    index: GlobalIndex        # its global index I_w (base coordinates)
    x: np.ndarray             # this worker's data shard
    y: np.ndarray
    plan: np.ndarray          # [steps, batch] make_batch_plan output


class FleetEngine:
    """Dispatches a list of FleetJobs to one of the three training engines."""

    def __init__(
        self,
        trainer: LocalTrainer,
        unit_map: UnitMap,
        base_shapes: Mapping[str, tuple],
        engine: str = "sequential",
    ):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
        self.trainer = trainer
        self.unit_map = unit_map
        self.base_shapes = base_shapes
        self.engine = engine
        self.batched_calls = 0    # device programs launched for batched phases
        self.buckets_used: set = set()   # sub-stack row counts launched
        self._mask_cache: Dict[tuple, Params] = {}

    @property
    def compute(self) -> str:
        """Device compute path of the masked/resident programs this engine
        launches ("dense" | "block_skip") — owned by the trainer."""
        return self.trainer.compute

    # ------------------------------------------------------------------
    def train_all(self, jobs: Sequence[FleetJob], lam: float = 0.0) -> List[Params]:
        """Train every job; returns reconfigured params aligned with ``jobs``."""
        results: List[Optional[Params]] = [None] * len(jobs)
        live = [i for i, j in enumerate(jobs) if j.plan.shape[0] > 0]
        for i, j in enumerate(jobs):
            if i not in live:   # empty plan: nothing to train
                results[i] = {k: np.asarray(v) for k, v in j.params.items()}
        if not live:
            return results  # type: ignore[return-value]
        if self.engine == "sequential":
            for i in live:
                j = jobs[i]
                results[i], _ = self.trainer.train_plan(
                    j.params, self.unit_map, j.x, j.y, j.plan, lam
                )
        elif self.engine == "bucketed":
            self._run_bucketed(jobs, live, results, lam)
        else:
            self._run_masked(jobs, live, results, lam)
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    @staticmethod
    def _shape_sig(params: Params) -> tuple:
        return tuple(sorted((k, v.shape) for k, v in params.items()))

    def _run_bucketed(self, jobs, live, results, lam):
        buckets: Dict[tuple, List[int]] = {}
        for i in live:
            j = jobs[i]
            key = (self._shape_sig(j.params), j.x.shape, j.plan.shape)
            buckets.setdefault(key, []).append(i)
        for key, members in buckets.items():
            js = [jobs[i] for i in members]
            trained, _ = self.trainer.train_many(
                [j.params for j in js],
                self.unit_map,
                np.stack([j.x for j in js]),
                np.stack([j.y for j in js]),
                np.stack([j.plan for j in js]),
                lam,
            )
            self.batched_calls += 1
            for i, p in zip(members, trained):
                results[i] = p

    def _mask_for(self, index: GlobalIndex) -> Params:
        key = tuple(sorted((k, tuple(map(int, v))) for k, v in index.items()))
        m = self._mask_cache.get(key)
        if m is None:
            m = {
                path: coordinate_mask(path, index, self.unit_map, self.base_shapes)
                .astype(np.float32)
                for path in self.base_shapes
            }
            self._mask_cache[key] = m
        return m

    def _run_masked(self, jobs, live, results, lam):
        # all workers share the base shape -> bucket only by shard/plan shape
        buckets: Dict[tuple, List[int]] = {}
        for i in live:
            j = jobs[i]
            buckets.setdefault((j.x.shape, j.plan.shape), []).append(i)
        for key, members in buckets.items():
            js = [jobs[i] for i in members]
            embedded = [
                embed_params(j.params, j.index, self.unit_map, self.base_shapes)
                for j in js
            ]
            masks = [self._mask_for(j.index) for j in js]
            # group-lasso sqrt|g| factors from the RECONFIGURED shapes, so the
            # penalty matches the physically small model, not the base shapes
            gl_sizes = [group_size_sqrt(j.params, self.unit_map) for j in js]
            trained, _ = self.trainer.train_many(
                embedded,
                self.unit_map,
                np.stack([j.x for j in js]),
                np.stack([j.y for j in js]),
                np.stack([j.plan for j in js]),
                lam,
                masks=masks,
                gl_sizes=gl_sizes,
            )
            self.batched_calls += 1
            for i, base_p in zip(members, trained):
                # hand back the reconfigured view the rest of the pipeline uses
                results[i] = extract_subparams(base_p, jobs[i].index, self.unit_map)

    # ------------------------------------------------------------------
    # resident fleet state: [W, ...] stacks that live on device
    # ------------------------------------------------------------------

    def init_state(
        self,
        base_params: Params,
        shards_x: Sequence[np.ndarray],
        shards_y: Sequence[np.ndarray],
        sharding=None,
    ) -> "FleetState":
        """Stack W full-model replicas + their data shards on device.

        Shards are padded to the longest shard; batch plans only ever index
        below each worker's true length, so the padding is never read.

        ``sharding`` (a ``NamedSharding`` from ``specs.fleet_sharding``, or
        None for the single-device layout) places every ``[W, ...]`` stack
        row-sharded over the fleet mesh axis — the state itself is
        sharding-agnostic: nothing downstream changes shape or dtype, rows
        just live on ``num_shards`` devices as ``W = num_shards x W_local``."""
        W = len(shards_x)
        sizes = np.array([len(x) for x in shards_x], dtype=np.int64)
        n_max = int(sizes.max())
        xs = np.zeros((W, n_max) + shards_x[0].shape[1:], shards_x[0].dtype)
        ys = np.zeros((W, n_max), shards_y[0].dtype)
        for w in range(W):
            xs[w, : sizes[w]] = shards_x[w]
            ys[w, : sizes[w]] = shards_y[w]
        n_shards = 1
        if sharding is not None:
            n_shards = int(np.prod([
                sharding.mesh.shape[a]
                for a in jax.tree.leaves(tuple(sharding.spec))
            ], dtype=np.int64)) or 1
            if W % n_shards:
                raise ValueError(
                    f"fleet of {W} workers does not divide over the "
                    f"{n_shards}-way fleet mesh axis"
                )
        put = (lambda v: jax.device_put(v, sharding)) if sharding is not None \
            else jnp.asarray
        params = {
            k: put(np.broadcast_to(
                np.asarray(v)[None], (W,) + tuple(v.shape)
            ))
            for k, v in base_params.items()
        }
        masks = {
            k: put(np.ones((W,) + tuple(v.shape), np.float32))
            for k, v in base_params.items()
        }
        state = FleetState(
            params=params, masks=masks, momentum=None,
            xs=put(xs), ys=put(ys),
            shard_sizes=sizes, num_workers=W,
            gl_sizes={
                lname: np.full((W,), s, np.float32)
                for lname, s in group_size_sqrt_from_shapes(
                    self.base_shapes, self.unit_map
                ).items()
            },
            sharding=sharding, num_shards=n_shards,
        )
        return state

    def update_shard(self, state: "FleetState", w: int, x: np.ndarray, y: np.ndarray):
        """Swap one worker's data shard in place (scenario churn join)."""
        n_max = state.xs.shape[1]
        if len(x) > n_max:
            raise ValueError(f"churn shard ({len(x)}) exceeds resident pad ({n_max})")
        xr = np.zeros((n_max,) + x.shape[1:], x.dtype)
        yr = np.zeros((n_max,), y.dtype)
        xr[: len(x)], yr[: len(y)] = x, y
        state.xs = state.xs.at[w].set(jnp.asarray(xr))
        state.ys = state.ys.at[w].set(jnp.asarray(yr))
        state.shard_sizes[w] = len(x)

    def refresh_masks(self, state: "FleetState", indices: Sequence[GlobalIndex]):
        """Rewrite the mask stack from the workers' global indices and re-mask
        the param stack.  This is the ONLY thing a pruning event does to the
        resident state — shapes never change, so nothing recompiles."""
        W = state.num_workers
        presence: Dict[str, np.ndarray] = {}
        for lname, dim in self._unit_dims().items():
            p = np.zeros((W, dim), np.float32)
            for w in range(W):
                p[w, np.asarray(indices[w][lname], np.int64)] = 1.0
            presence[lname] = p
        for path, shape in self.base_shapes.items():
            m = np.ones((W,) + tuple(shape), np.float32)
            for lname, axis in self.unit_map.get(path, ()):
                bshape = [W] + [1] * len(shape)
                bshape[1 + axis] = shape[axis]
                m = m * presence[lname].reshape(bshape)
            state.masks[path] = jnp.asarray(m)
            state.params[path] = state.params[path] * state.masks[path]
            if state.momentum is not None:
                # cross-round momentum rows are masked like the params, so a
                # pruned unit's velocity dies with it (matching the fused
                # engine's in-scan prune)
                state.momentum[path] = state.momentum[path] * state.masks[path]
        for w in range(W):
            shapes = subparam_shapes(indices[w], self.unit_map, self.base_shapes)
            for lname, s in group_size_sqrt_from_shapes(shapes, self.unit_map).items():
                state.gl_sizes[lname][w] = s

    def _unit_dims(self) -> Dict[str, int]:
        dims: Dict[str, int] = {}
        for path, entries in self.unit_map.items():
            for lname, axis in entries:
                dims[lname] = self.base_shapes[path][axis]
        return dims

    def scatter_global(self, state: "FleetState", global_params: Params):
        """Broadcast-back (Alg. 1 server line 9) as a masked scatter:
        ``P = theta_g[None] * M`` — extract/embed never run."""
        for path, g in global_params.items():
            state.params[path] = jnp.asarray(g)[None] * state.masks[path]

    def scatter_global_rows(
        self,
        state: "FleetState",
        rows: Sequence[int],
        globals_list: Sequence[Params],
    ):
        """Masked scatter of per-row global snapshots into the resident stack:
        row ``rows[i]`` becomes ``globals_list[i] * M[rows[i]]``.

        This is the async schedulers' refetch path — each committing worker
        refetched a *different* global version, so the rows are stacked on
        host once per fleet call and written in one device op per tensor."""
        idx = jnp.asarray(np.asarray(rows, np.int64))
        for path in state.params:
            g = jnp.asarray(np.stack([gl[path] for gl in globals_list]))
            state.params[path] = state.params[path].at[idx].set(
                g * jnp.take(state.masks[path], idx, axis=0)
            )

    def stack_plans(
        self,
        plans: Sequence[Optional[np.ndarray]],
        pad_rows: Optional[int] = None,
        pad_steps: Optional[int] = None,
    ):
        """Pad per-worker batch plans into ``[R, S, batch]`` + a ``[R, S]``
        validity mask (see ``worker.stack_batch_plans``).  Returns ``None``
        when no worker has a real step this phase."""
        stacked = stack_batch_plans(plans, num_rows=pad_rows, num_steps=pad_steps)
        if stacked is None:
            return None
        stack, valid = stacked
        return jnp.asarray(stack), jnp.asarray(valid)

    def init_momentum(self, state: "FleetState"):
        """Zero the momentum stack for the cross-round resident-momentum
        mode (``SimConfig.resident_momentum``)."""
        state.momentum = {k: jnp.zeros_like(v) for k, v in state.params.items()}

    def zero_momentum_rows(self, state: "FleetState", rows: Sequence[int]):
        """Restart momentum for a set of worker rows in place.

        The one momentum-reset primitive behind both slot churn and
        crash-recovery re-entry: a returning worker refetches the global
        (the ordinary ``scatter_global`` broadcast-back) but must not reuse
        velocity accumulated against pre-crash parameters."""
        if state.momentum is None or not len(rows):
            return
        idx = jnp.asarray(np.asarray(rows, np.int64))
        state.momentum = {
            k: v.at[idx].set(0.0) for k, v in state.momentum.items()
        }

    def train_rounds(
        self,
        state: "FleetState",
        plans: Sequence[Optional[np.ndarray]],
        lam: float = 0.0,
        pad_steps: Optional[int] = None,
        carry_momentum: bool = False,
    ) -> Optional[np.ndarray]:
        """One resident device program for a whole round phase.

        Rows whose plan is ``None``/empty are not trained *and not computed*:
        when fewer than W slots have work, the active rows are gathered into
        a bucket-sized sub-stack first (``train_rows``), so device FLOPs
        track participation.  Returns per-worker mean losses aligned to the
        full slot space (idle rows report 0), or ``None`` if no worker had
        work this phase.  ``carry_momentum`` feeds ``state.momentum`` into
        the optimizer and keeps the trained stack as the next carry (the
        cross-round resident-momentum mode) instead of the default per-phase
        zero restart."""
        W = state.num_workers
        rows = [w for w, p in enumerate(plans) if p is not None and p.shape[0] > 0]
        if not rows:
            return None
        if len(rows) == W:
            stacked = self.stack_plans(plans, pad_steps=pad_steps)
            plan_stack, valid = stacked
            gl = {k: jnp.asarray(v) for k, v in state.gl_sizes.items()}
            state.params, state.momentum, losses = self.trainer.train_resident(
                state.params, state.masks, self.unit_map,
                state.xs, state.ys, plan_stack, valid, lam, gl,
                momentum_in=state.momentum if carry_momentum else None,
            )
            self.batched_calls += 1
            self.buckets_used.add(W)
            return np.asarray(losses)
        losses, _ = self.train_rows(
            state, rows, [plans[w] for w in rows], lam, pad_steps=pad_steps,
            carry_momentum=carry_momentum,
        )
        full = np.zeros(W, np.float32)
        full[rows] = losses
        return full

    def train_rows(
        self,
        state: "FleetState",
        rows: Sequence[int],
        plans: Sequence[Optional[np.ndarray]],
        lam: float = 0.0,
        pad_steps: Optional[int] = None,
        to_host: bool = False,
        carry_momentum: bool = False,
    ) -> Tuple[np.ndarray, Optional[Dict[str, np.ndarray]]]:
        """Participation-sized resident training: gather ``rows`` into a
        ``[B, ...]`` sub-stack (B = next row bucket), run ONE vmapped scan
        over it, scatter the trained rows back into the resident stacks.

        ``plans`` aligns with ``rows``.  Returns ``(losses[len(rows)],
        trained)`` where ``trained`` is a single host copy of the trained
        ``{path: [len(rows), ...]}`` rows when ``to_host`` is set (the async
        schedulers' stacked aggregate out) and ``None`` otherwise."""
        W = state.num_workers
        B = len(rows)
        bucket = bucket_rows(B, W, multiple=state.num_shards)
        rows = [int(w) for w in rows]
        rows_pad = rows + [rows[0]] * (bucket - B)
        stacked = self.stack_plans(
            list(plans) + [None] * (bucket - B),
            pad_rows=bucket, pad_steps=pad_steps,
        )
        if stacked is None:
            return np.zeros(B, np.float32), None
        plan_stack, valid = stacked
        sub_params = gather_stack_rows(state.params, rows_pad, num_rows=W)
        sub_masks = gather_stack_rows(state.masks, rows_pad, num_rows=W)
        idx = jnp.asarray(np.asarray(rows_pad, np.int64))
        xs = jnp.take(state.xs, idx, axis=0)
        ys = jnp.take(state.ys, idx, axis=0)
        gl = {
            k: jnp.asarray(np.asarray(v)[rows_pad]) for k, v in state.gl_sizes.items()
        }
        mom_in = (
            gather_stack_rows(state.momentum, rows_pad) if carry_momentum else None
        )
        out, mom_out, losses = self.trainer.train_resident(
            sub_params, sub_masks, self.unit_map, xs, ys, plan_stack, valid, lam, gl,
            momentum_in=mom_in,
        )
        self.batched_calls += 1
        self.buckets_used.add(bucket)
        state.params = scatter_stack_rows(state.params, rows, out, num_rows=W)
        if carry_momentum:
            # cross-round mode: the trained rows' velocity is the next carry
            state.momentum = scatter_stack_rows(
                state.momentum, rows, mom_out, num_rows=W
            )
        # otherwise state.momentum (a full-stack observational snapshot,
        # nothing reads it) is left untouched — momentum restarts per phase
        trained = (
            {k: np.asarray(v[:B]) for k, v in out.items()} if to_host else None
        )
        return np.asarray(losses)[:B], trained

    def params_host(self, state: "FleetState") -> Dict[str, np.ndarray]:
        """Host view of the resident param stack (submission boundary only)."""
        return {k: np.asarray(v) for k, v in state.params.items()}

    def masks_host(self, state: "FleetState") -> Dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in state.masks.items()}


@dataclasses.dataclass
class FleetState:
    """Resident multi-worker state: everything is a ``[W, ...]`` stack.

    ``params`` rows are always masked (pruned coordinates exactly 0), so
    stacked aggregation can consume them directly; ``momentum`` is a purely
    observational snapshot of the last FULL-stack phase's optimizer state
    (momentum restarts per phase, matching the per-worker engines, and
    participation-sized sub-stack phases do not update it).  ``shard_sizes``
    records true (pre-padding) shard
    lengths; ``gl_sizes`` the per-worker sqrt-group-size factors that keep
    the group-lasso penalty equal to each physically-reconfigured twin.

    ``sharding``/``num_shards`` record the mesh placement of the stacks
    (``specs.fleet_sharding`` row-sharding over a fleet axis, or None/1 on a
    single device): the state is sharding-AGNOSTIC — shapes, dtypes and
    every consumer are identical either way, rows just live on
    ``num_shards`` devices as ``W = num_shards x W_local``."""

    params: Dict[str, jnp.ndarray]
    masks: Dict[str, jnp.ndarray]
    momentum: Optional[Dict[str, jnp.ndarray]]
    xs: jnp.ndarray
    ys: jnp.ndarray
    shard_sizes: np.ndarray
    num_workers: int
    gl_sizes: Dict[str, np.ndarray]
    sharding: Optional[object] = None
    num_shards: int = 1
