"""Fleet engine: shape-bucketed / masked vmapped local training (DESIGN §IV).

The simulator's cost model is the paper's; its *host* cost used to be W
sequential ``LocalTrainer.train`` calls per round, with one fresh jit per
distinct pruned shape — wall-clock linear in workers, recompiles linear in
pruning diversity.  This module batches the fleet:

* ``sequential`` — reference engine: one scan-train call per worker, in
  worker order.  Numerically the baseline the other engines are tested
  against.
* ``bucketed``   — workers whose sub-models share a parameter-shape
  signature (and shard/plan shapes) are stacked and trained in ONE jitted
  ``vmap``-of-``scan`` program (stacked params, stacked shards, stacked
  optimizer state, per-worker batch plans).  W homogeneous workers → one
  compile, one device program.
* ``masked``     — every worker stays at BASE shape; its sub-model is a 0/1
  coordinate mask (same masking idiom as ``kernels/pruned_matmul``: prune =
  multiply by zero, never reshape), so *all* workers bucket together and
  pruning events trigger **zero** reconfigure-recompiles (compiles happen
  only per distinct fleet-stack shape — e.g. a different number of phase-B
  pruners — never because a sub-model changed shape).  Masked training
  is numerically equivalent to reconfigured training for the CNN family
  here: a fully-masked filter produces exactly-zero activations, BN of an
  all-zero channel is ``(0)*rsqrt(eps)*0+0 = 0``, and masked-loss gradients
  vanish on pruned coordinates, so retained coordinates see the same
  function as the physically-small model.

Every engine consumes identical pre-drawn batch plans (``make_batch_plan``),
which is what the equivalence tests pin down.  Compiles are counted in the
underlying ``LocalTrainer.compile_count`` and surfaced as
``SimResult.recompiles``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.optim.group_lasso import group_size_sqrt

from .aggregation import UnitMap, coordinate_mask, embed_params, extract_subparams
from .masks import GlobalIndex
from .worker import LocalTrainer, Params

__all__ = ["ENGINES", "FleetJob", "FleetEngine"]

ENGINES = ("sequential", "bucketed", "masked")


@dataclasses.dataclass
class FleetJob:
    """One worker's local-training work item for a round phase."""

    worker: int
    params: Params            # reconfigured (physically small) sub-model
    index: GlobalIndex        # its global index I_w (base coordinates)
    x: np.ndarray             # this worker's data shard
    y: np.ndarray
    plan: np.ndarray          # [steps, batch] make_batch_plan output


class FleetEngine:
    """Dispatches a list of FleetJobs to one of the three training engines."""

    def __init__(
        self,
        trainer: LocalTrainer,
        unit_map: UnitMap,
        base_shapes: Mapping[str, tuple],
        engine: str = "sequential",
    ):
        if engine not in ENGINES:
            raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
        self.trainer = trainer
        self.unit_map = unit_map
        self.base_shapes = base_shapes
        self.engine = engine
        self.batched_calls = 0    # device programs launched for batched phases
        self._mask_cache: Dict[tuple, Params] = {}

    # ------------------------------------------------------------------
    def train_all(self, jobs: Sequence[FleetJob], lam: float = 0.0) -> List[Params]:
        """Train every job; returns reconfigured params aligned with ``jobs``."""
        results: List[Optional[Params]] = [None] * len(jobs)
        live = [i for i, j in enumerate(jobs) if j.plan.shape[0] > 0]
        for i, j in enumerate(jobs):
            if i not in live:   # empty plan: nothing to train
                results[i] = {k: np.asarray(v) for k, v in j.params.items()}
        if not live:
            return results  # type: ignore[return-value]
        if self.engine == "sequential":
            for i in live:
                j = jobs[i]
                results[i], _ = self.trainer.train_plan(
                    j.params, self.unit_map, j.x, j.y, j.plan, lam
                )
        elif self.engine == "bucketed":
            self._run_bucketed(jobs, live, results, lam)
        else:
            self._run_masked(jobs, live, results, lam)
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------
    @staticmethod
    def _shape_sig(params: Params) -> tuple:
        return tuple(sorted((k, v.shape) for k, v in params.items()))

    def _run_bucketed(self, jobs, live, results, lam):
        buckets: Dict[tuple, List[int]] = {}
        for i in live:
            j = jobs[i]
            key = (self._shape_sig(j.params), j.x.shape, j.plan.shape)
            buckets.setdefault(key, []).append(i)
        for key, members in buckets.items():
            js = [jobs[i] for i in members]
            trained, _ = self.trainer.train_many(
                [j.params for j in js],
                self.unit_map,
                np.stack([j.x for j in js]),
                np.stack([j.y for j in js]),
                np.stack([j.plan for j in js]),
                lam,
            )
            self.batched_calls += 1
            for i, p in zip(members, trained):
                results[i] = p

    def _mask_for(self, index: GlobalIndex) -> Params:
        key = tuple(sorted((k, tuple(map(int, v))) for k, v in index.items()))
        m = self._mask_cache.get(key)
        if m is None:
            m = {
                path: coordinate_mask(path, index, self.unit_map, self.base_shapes)
                .astype(np.float32)
                for path in self.base_shapes
            }
            self._mask_cache[key] = m
        return m

    def _run_masked(self, jobs, live, results, lam):
        # all workers share the base shape -> bucket only by shard/plan shape
        buckets: Dict[tuple, List[int]] = {}
        for i in live:
            j = jobs[i]
            buckets.setdefault((j.x.shape, j.plan.shape), []).append(i)
        for key, members in buckets.items():
            js = [jobs[i] for i in members]
            embedded = [
                embed_params(j.params, j.index, self.unit_map, self.base_shapes)
                for j in js
            ]
            masks = [self._mask_for(j.index) for j in js]
            # group-lasso sqrt|g| factors from the RECONFIGURED shapes, so the
            # penalty matches the physically small model, not the base shapes
            gl_sizes = [group_size_sqrt(j.params, self.unit_map) for j in js]
            trained, _ = self.trainer.train_many(
                embedded,
                self.unit_map,
                np.stack([j.x for j in js]),
                np.stack([j.y for j in js]),
                np.stack([j.plan for j in js]),
                lam,
                masks=masks,
                gl_sizes=gl_sizes,
            )
            self.batched_calls += 1
            for i, base_p in zip(members, trained):
                # hand back the reconfigured view the rest of the pipeline uses
                results[i] = extract_subparams(base_p, jobs[i].index, self.unit_map)
