"""Update-time and heterogeneity model (AdaptCL Eq. 4, 6, 7, 8).

The paper emulates heterogeneity by assigning each worker a bandwidth B_w so
that update times are uniformly spread between the fastest worker and
``sigma``x the fastest:

    phi_w = (2*s_model/B_max + t_train) * (1 + (sigma-1)/(W-1) * (W-w))   (Eq. 6)
    B_w   = 2*s_model / (phi_w - t_train)                                  (Eq. 7)
    H     = 1 - 1/(W-1) * sum_w 1/(1 + (sigma-1)/(W-1)*(W-w))              (Eq. 8)

We reuse the same channel model to *simulate* worker update times as a
function of the retention ratio gamma:

    phi_w(gamma) = 2 * s_model(gamma) / B_w + t_train(gamma)

where s_model(gamma) is the actual parameter payload of the reconfigured
sub-model and t_train(gamma) the measured (or modelled) per-round train time.
Training-time sensitivity to pruning is device-dependent (paper Appendix E):
``train_sens`` in [0,1] linearly interpolates between "insensitive" (GPU-like,
t_train const) and "fully proportional" (CPU-like, t_train ~ FLOPs(gamma)).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence

import numpy as np

__all__ = [
    "HeterogeneityConfig",
    "drift_multiplier",
    "heterogeneity_from_times",
    "heterogeneity_closed_form",
    "make_bandwidths",
    "ChannelModel",
]


def drift_multiplier(
    round_t: int, start_round: int, factor: float, ramp_rounds: int = 1
) -> float:
    """Capability-drift update-time multiplier at 1-based round ``round_t``.

    Before ``start_round`` the multiplier is 1; from
    ``start_round + ramp_rounds - 1`` on it is ``factor``; a ramp
    interpolates linearly in between (``ramp_rounds == 1`` is a jump).
    Pure and deterministic — every engine computes the identical drift
    curve without consuming any RNG stream."""
    if round_t < start_round:
        return 1.0
    if ramp_rounds <= 1 or round_t >= start_round + ramp_rounds - 1:
        return float(factor)
    frac = (round_t - start_round + 1) / float(ramp_rounds)
    return float(1.0 + (factor - 1.0) * frac)


@dataclasses.dataclass(frozen=True)
class HeterogeneityConfig:
    num_workers: int = 10
    sigma: float = 2.0        # longest/shortest update-time ratio
    # bytes/s of the fastest worker (paper: 5 MB).  None => auto-scale so that
    # comm_fast = comm_ratio * t_train (reproduces the paper's comm-dominated
    # regime regardless of simulated model size).
    bandwidth_max: float | None = None
    comm_ratio: float = 3.0


def heterogeneity_from_times(phis: Sequence[float]) -> float:
    """H = 1 - 1/(W-1) * sum_{w != argmin} phi_min/phi_w   (Eq. 4)."""
    phis = np.asarray(phis, dtype=np.float64)
    W = phis.size
    if W < 2:
        return 0.0
    phi_min = phis.min()
    idx_min = int(phis.argmin())
    others = np.delete(phis, idx_min)
    return float(1.0 - np.mean(phi_min / others))


def heterogeneity_closed_form(W: int, sigma: float) -> float:
    """Eq. 8 — H for the uniform spread used in the experiments."""
    if W < 2:
        return 0.0  # a lone worker is its own fastest peer (matches Eq. 4)
    ws = np.arange(1, W, dtype=np.float64)  # w = 1..W-1 (worker W is fastest)
    return float(1.0 - np.mean(1.0 / (1.0 + (sigma - 1.0) / (W - 1) * (W - ws))))


def make_bandwidths(
    cfg: HeterogeneityConfig, model_bytes: float, t_train: float
) -> List[float]:
    """Eq. 6/7: bandwidths giving uniformly spread update times.

    Worker index W (last) is the fastest, matching the paper's Tab. VI-VIII
    (ascending bandwidth lists ending at B_max).
    """
    W, sigma = cfg.num_workers, cfg.sigma
    bmax = cfg.bandwidth_max
    if bmax is None:
        bmax = 2.0 * model_bytes / (cfg.comm_ratio * max(t_train, 1e-9))
    phi_fast = 2.0 * model_bytes / bmax + t_train
    if W == 1:
        # Degenerate fleet: the spread term (W - w) is identically zero, so
        # phi_1 = phi_fast and B_1 = bmax exactly.
        return [bmax]
    bws = []
    for w in range(1, W + 1):
        phi_w = phi_fast * (1.0 + (sigma - 1.0) / (W - 1) * (W - w))
        bws.append(2.0 * model_bytes / (phi_w - t_train))
    return bws


@dataclasses.dataclass
class ChannelModel:
    """Per-worker update-time simulator phi_w(gamma).

    model_bytes_fn: gamma -> payload bytes of the reconfigured sub-model.
    flops_fn:       gamma -> per-round training FLOPs of the sub-model.
    train_sens:     0.0 = train time insensitive to pruning (GPU-like),
                    1.0 = proportional to FLOPs (CPU-like). Appendix E.
    jitter:         multiplicative noise std on each observation (bandwidth
                    fluctuation); the pruning-interval averaging in the
                    server is what suppresses this.
    """

    bandwidths: Sequence[float]
    t_train_full: float
    model_bytes_fn: Callable[[float], float]
    flops_fn: Callable[[float], float]
    train_sens: float = 0.0
    jitter: float = 0.0

    def train_time(self, gamma: float) -> float:
        rel = self.flops_fn(gamma) / max(self.flops_fn(1.0), 1e-30)
        return self.t_train_full * ((1.0 - self.train_sens) + self.train_sens * rel)

    def comm_time(self, worker: int, gamma: float) -> float:
        return 2.0 * self.model_bytes_fn(gamma) / self.bandwidths[worker]

    def update_time(
        self, worker: int, gamma: float, rng: np.random.Generator | None = None
    ) -> float:
        phi = self.comm_time(worker, gamma) + self.train_time(gamma)
        if self.jitter > 0.0 and rng is not None:
            phi *= float(np.exp(rng.normal(0.0, self.jitter)))
        return phi
