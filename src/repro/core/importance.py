"""Unit-importance criteria for distributed pruning (AdaptCL §III-D, Fig. 2).

An importance method returns a score per prunable unit (higher = keep).  The
mask machinery (`core.masks`) cuts the lowest-scored *retained* units to meet
a per-worker pruned-rate budget.

The paper's finding (distributed-pruning principles): the retained sets must
be **Identical** across workers and **Constant** over rounds so that
sub-models nest.  Its proposed method is **CIG-BNscalor** — a single global
importance ranking frozen at the first pruning, taken from BN scaling factors
of the aggregated global model.  For the RMSNorm transformer families in the
assigned pool we use the per-unit norm-scale magnitude (mean |scale| over the
unit's channels) as the data-independent analogue (documented in DESIGN.md §5);
where no scale exists we fall back to the unit's weight L2 norm computed on
the *global* model — still Constant/Identical/Global.

Ablation + baseline criteria reproduce Fig. 2:
  * index          — HeteroFL-style prefix retention (prune highest index first)
  * no_adjacent    — one shared random order, constant
  * no_identical   — per-worker random rotation, constant  (breaks Identical)
  * no_constant    — shared rotation re-drawn each round    (breaks Constant)
  * l1 / taylor / fpgm / hrank — data/sub-model-dependent criteria (break both)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = [
    "ImportanceContext",
    "ImportanceMethod",
    "cig_scores_from_scales",
    "cig_scores_from_weight_norms",
    "METHODS",
    "STATIC_METHODS",
    "DEVICE_METHODS",
    "l1_scores_jnp",
    "taylor_scores_jnp",
    "flat_scores_jnp",
    "grad_magnitude_scores",
]

Scores = Dict[str, np.ndarray]


@dataclasses.dataclass
class ImportanceContext:
    """Everything a criterion may consult.

    unit_counts:  layer -> number of units in the *base* model.
    scales:       layer -> per-unit scale magnitudes from the aggregated
                  global model (BN gamma for CNNs, norm-scale proxy for
                  transformers). Data-independent.
    weight_norms: layer -> per-unit L2 norm of the unit's weight group in the
                  *local sub-model* (data/sub-model dependent once models
                  diverge).
    grads:        layer -> per-unit |grad . w| Taylor term (local, optional).
    activations:  layer -> per-unit activation statistic (local, optional;
                  HRank proxy).
    worker:       worker id (for Identical-breaking variants).
    round:        pruning round (for Constant-breaking variants).
    seed:         base seed shared by the cohort.
    """

    unit_counts: Mapping[str, int]
    scales: Optional[Scores] = None
    weight_norms: Optional[Scores] = None
    grads: Optional[Scores] = None
    activations: Optional[Scores] = None
    worker: int = 0
    round: int = 0
    seed: int = 0


ImportanceMethod = Callable[[ImportanceContext], Scores]


def cig_scores_from_scales(ctx: ImportanceContext) -> Scores:
    """CIG-BNscalor: frozen global scale-magnitude ranking (paper §III-D)."""
    if ctx.scales is None:
        return cig_scores_from_weight_norms(ctx)
    return {k: np.asarray(v, dtype=np.float64) for k, v in ctx.scales.items()}


def cig_scores_from_weight_norms(ctx: ImportanceContext) -> Scores:
    if ctx.weight_norms is None:
        raise ValueError("CIG fallback needs weight_norms")
    return {k: np.asarray(v, dtype=np.float64) for k, v in ctx.weight_norms.items()}


def _index(ctx: ImportanceContext) -> Scores:
    # Retain the prefix: higher index = pruned first (HeteroFL [50]).
    return {k: -np.arange(n, dtype=np.float64) for k, n in ctx.unit_counts.items()}


def _shared_random(ctx: ImportanceContext) -> Scores:
    # "No adjacent": a single random order shared by all workers, all rounds.
    rng = np.random.default_rng(ctx.seed)  # NOT worker/round dependent
    return {
        k: rng.permutation(n).astype(np.float64)
        for k, n in sorted(ctx.unit_counts.items())
    }


def _rotated_index(n: int, start: int) -> np.ndarray:
    # score so that units are pruned in index order beginning at `start`
    # (units just below `start` are the most important).
    idx = np.arange(n)
    return -(((idx - start) % n).astype(np.float64))


def _no_identical(ctx: ImportanceContext) -> Scores:
    # per-worker random start, constant across rounds.
    rng = np.random.default_rng((ctx.seed, ctx.worker))
    return {
        k: _rotated_index(n, int(rng.integers(n)))
        for k, n in sorted(ctx.unit_counts.items())
    }


def _no_constant(ctx: ImportanceContext) -> Scores:
    # shared start re-drawn at each pruning round.
    rng = np.random.default_rng((ctx.seed, ctx.round))
    return {
        k: _rotated_index(n, int(rng.integers(n)))
        for k, n in sorted(ctx.unit_counts.items())
    }


def _l1(ctx: ImportanceContext) -> Scores:
    if ctx.weight_norms is None:
        raise ValueError("l1 needs weight_norms")
    return {k: np.asarray(v, np.float64) for k, v in ctx.weight_norms.items()}


def _taylor(ctx: ImportanceContext) -> Scores:
    if ctx.grads is None:
        raise ValueError("taylor needs grads")
    return {k: np.asarray(v, np.float64) for k, v in ctx.grads.items()}


def _fpgm(ctx: ImportanceContext) -> Scores:
    """Geometric-median distance proxy: |norm - median(norm)| per layer.

    (True FPGM uses filter-vector distances; with per-unit summaries the
    distance-from-median of the norm is the standard cheap surrogate and
    reproduces the property that matters here: data/sub-model dependence.)
    """
    if ctx.weight_norms is None:
        raise ValueError("fpgm needs weight_norms")
    out = {}
    for k, v in ctx.weight_norms.items():
        v = np.asarray(v, np.float64)
        out[k] = np.abs(v - np.median(v))
    return out


def _hrank(ctx: ImportanceContext) -> Scores:
    if ctx.activations is None:
        raise ValueError("hrank needs activations")
    return {k: np.asarray(v, np.float64) for k, v in ctx.activations.items()}


def grad_magnitude_scores(
    grads: Mapping[str, np.ndarray],
    unit_map: Mapping[str, Sequence],
    unit_counts: Mapping[str, int],
) -> Scores:
    """FedDST/RigL grow signal: per-unit group sums of |grad|.

    ``grads`` are DENSE (unmasked) gradients in base coordinates — pruned
    slots carry real gradient signal, which is exactly what regrowth ranks.
    Accumulated in float64 so host grow orders are reproducible regardless
    of the device's accumulation dtype."""
    acc: Dict[str, np.ndarray] = {
        k: np.zeros(n, np.float64) for k, n in unit_counts.items()
    }
    for path, entries in unit_map.items():
        g = grads.get(path)
        if g is None:
            continue
        g = np.abs(np.asarray(g, np.float64))
        for lname, axis in entries:
            if lname not in acc:
                continue
            axes = tuple(i for i in range(g.ndim) if i != axis)
            acc[lname] += g.sum(axis=axes)
    return acc


METHODS: Dict[str, ImportanceMethod] = {
    "cig_bnscalor": cig_scores_from_scales,
    "index": _index,
    "no_adjacent": _shared_random,
    "no_identical": _no_identical,
    "no_constant": _no_constant,
    "l1": _l1,
    "taylor": _taylor,
    "fpgm": _fpgm,
    "hrank": _hrank,
}

# Criteria that satisfy the paper's Identical+Constant principles. Only these
# guarantee nested sub-models (masks.assert_nested holds for any two workers).
CIG_METHODS = frozenset({"cig_bnscalor", "index", "no_adjacent"})

# Criteria whose scores are data-independent — they depend only on (seed,
# worker, prune round, frozen global scales), all of which the fused round
# engine knows on the host at a chunk boundary, so their removal ORDERS can
# be precomputed host-exactly (``masks.prune_order``) and shipped to device
# as integer permutations.  For the seed-derived members the prune indices
# are therefore UNCONDITIONALLY bit-identical to the host path;
# cig_bnscalor's frozen scores read the trained global at the freeze event,
# so cross-engine float32 training/aggregation drift could in principle
# reorder a near-tie (the equivalence tests pin index equality on real
# runs).
STATIC_METHODS = CIG_METHODS | {"no_identical", "no_constant"}


# --- device-side (jnp) scorer transforms -----------------------------------
#
# Data-dependent criteria can't be frozen at a chunk boundary: their scores
# read the worker's CURRENT sub-model (and shard), which only exists on
# device inside a fused chunk.  These transforms mirror the host methods'
# scatter semantics — a non-retained unit scores -inf, exactly like
# ``worker.local_unit_stats`` scattering into a ``-inf``-filled base vector —
# over stacked ``[W, U]`` flat score rows.  The fused engine computes the
# raw signals (masked unit norms, |g.w| sums) on device and sorts with the
# same (score, layer, unit) lexicographic tie-break as the host
# (``UnitFlat.tiebreak``); float32-vs-float64 summation can reorder
# near-exact ties, which is why only ``STATIC_METHODS`` carry the
# bit-identical guarantee.

DEVICE_METHODS = frozenset({"l1", "taylor"})


def flat_scores_jnp(
    per_layer: Mapping[str, jnp.ndarray],   # {lname: [W, n_l]}
    layer_names: Sequence[str],
    presence: jnp.ndarray,                  # [W, U] 0/1 flat presence
) -> jnp.ndarray:
    """Concatenate per-layer score rows into ``[W, U]`` flat-slot order and
    apply the -inf scatter for non-retained units."""
    flat = jnp.concatenate([per_layer[name] for name in layer_names], axis=1)
    return jnp.where(presence > 0, flat, -jnp.inf)


def l1_scores_jnp(weight_norms, layer_names, presence) -> jnp.ndarray:
    """L1 criterion on device: per-unit group norms of the masked stacks."""
    return flat_scores_jnp(weight_norms, layer_names, presence)


def taylor_scores_jnp(grad_weight, layer_names, presence) -> jnp.ndarray:
    """Taylor |g.w| criterion on device."""
    return flat_scores_jnp(grad_weight, layer_names, presence)
