"""Model configuration shared by all assigned architectures.

`block_pattern` tiles over `num_layers` (remainder layers allowed); with
`scan_layers=True` full pattern periods are stacked and scanned (small HLO,
fast multi-pod compiles) and remainder layers are unrolled.

AdaptCL integration: `retention` < 1 means this config is a *reconfigured
sub-model* of the base (see `apply_retention`), the JAX analogue of the
paper's NetworkReconfigure — physically smaller arrays, new executable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = ["ModelConfig", "apply_retention", "param_count", "flops_per_token"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None      # None => d_model // num_heads
    block_pattern: Tuple[str, ...] = ("attn",)
    window_size: Optional[int] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    gated_ffn: bool = True
    activation: str = "silu"
    norm_style: str = "rms"             # rms | layernorm
    pos_embed: str = "rope"             # rope | learned
    max_position: int = 32768           # learned-pos table size
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    embed_scale: bool = False           # gemma-style sqrt(d) embedding scale
    # --- MoE ---
    num_experts: int = 0
    experts_per_tok: int = 0
    shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    # --- recurrent ---
    rnn_width: Optional[int] = None     # RG-LRU lru width
    rnn_heads: int = 16                 # RG-LRU gate blocks
    xlstm_proj_factor: float = 2.0
    # --- enc-dec / multimodal ---
    encoder_layers: int = 0
    frontend: Optional[str] = None      # None | "audio" | "vision" (stubs)
    num_prefix_embeds: int = 0          # patch/frame embeddings in the seq
    # --- execution ---
    dtype: str = "float32"
    attn_q_block: Optional[int] = 1024  # q-block size for memory-safe attention
    # shard the residual stream's seq dim over the model axis at layer
    # boundaries (context-parallel activations): divides remat-save memory by
    # the model-axis size at the cost of per-layer seq all-gathers (§Perf)
    seq_shard_activations: bool = False
    scan_layers: bool = True
    remat: bool = True
    # --- AdaptCL ---
    retention: float = 1.0              # gamma of this (sub-)model
    # when vocab_size was padded up for sharding divisibility, the real size
    # (logits above it are masked to -inf in _logits)
    vocab_size_real: Optional[int] = None

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def layer_kinds(self) -> Tuple[str, ...]:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))


def _snap(x: float, mult: int, lo: int) -> int:
    return max(lo, int(round(x / mult)) * mult)


def apply_retention(cfg: ModelConfig, gamma: float, prune_heads: bool = False) -> ModelConfig:
    """NetworkReconfigure at config level: uniform unit retention gamma.

    Production path (``prune_heads=False``, default): prunes FFN columns /
    experts / recurrent channels and keeps attention heads — head counts are
    tied to the tensor-parallel mesh factorization (e.g. 16 heads on a 16-way
    model axis), and shrinking them would unshard attention (measured: 2x
    *worse* memory at gamma=0.6 — EXPERIMENTS.md §Perf pair 3).  The
    FL-simulation path prunes head groups freely (no TP there); pass
    ``prune_heads=True`` to reproduce that behaviour at config level.

    Dims snap to sharding-friendly multiples; the *achieved* retention is
    param_count(sub)/param_count(base), reported by callers.
    """
    if not (0.0 < gamma <= 1.0):
        raise ValueError(f"gamma {gamma} outside (0, 1]")
    if gamma == 1.0:
        return cfg
    kw = dict(
        d_ff=_snap(cfg.d_ff * gamma, 128, 128) if cfg.d_ff else 0,
        retention=gamma,
    )
    if prune_heads:
        kv = max(1, int(round(cfg.num_kv_heads * gamma)))
        kw["num_kv_heads"] = kv
        kw["num_heads"] = kv * cfg.q_per_kv
    if cfg.num_experts:
        kw["num_experts"] = max(max(1, cfg.experts_per_tok), int(round(cfg.num_experts * gamma)))
    if cfg.rnn_width:
        kw["rnn_width"] = _snap(cfg.rnn_width * gamma, cfg.rnn_heads * 8, cfg.rnn_heads * 8)
    if any(k in ("mlstm", "slstm") for k in cfg.block_pattern):
        # xLSTM width lives in the cell projections (d_ff = 0)
        pf = cfg.xlstm_proj_factor * gamma
        # keep d_inner a multiple of 128*heads for MXU/sharding alignment
        di = _snap(cfg.d_model * pf, 128 * cfg.num_heads // cfg.num_heads, 128)
        kw["xlstm_proj_factor"] = di / cfg.d_model
    return cfg.replace(**kw)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (embedding + blocks + head)."""
    D, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    attn = D * H * hd + 2 * D * KV * hd + H * hd * D
    if cfg.qkv_bias:
        attn += (H + 2 * KV) * hd
    ffn = (3 if cfg.gated_ffn else 2) * D * cfg.d_ff
    moe = 0
    if cfg.num_experts:
        moe = cfg.num_experts * 3 * D * cfg.d_ff + D * cfg.num_experts
        if cfg.shared_expert:
            moe += 3 * D * cfg.d_ff
        ffn = 0
    total = 0
    for kind in cfg.layer_kinds():
        if kind == "attn" or kind == "local":
            total += attn + ffn + 2 * D
        elif kind == "moe":
            total += attn + moe + 2 * D
        elif kind == "rglru":
            R = cfg.rnn_width or D
            blocks = 2 * (R // cfg.rnn_heads) ** 2 * cfg.rnn_heads
            total += 2 * D * R + 4 * R + blocks + R * D + ffn + 2 * D
        elif kind in ("mlstm", "slstm"):
            DI = int(D * cfg.xlstm_proj_factor)
            if kind == "mlstm":
                total += 2 * D * DI + 3 * DI * DI + 2 * DI + DI * D + D
            else:
                total += D * DI + 4 * DI * DI + DI + DI * D + D
    total += cfg.vocab_size * D  # embedding
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * D
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (attn + ffn + 2 * D) + cfg.max_position * D
    total += D  # final norm
    return int(total)


def flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    """MODEL_FLOPS per token: 6*N_active (+ attention quadratic term)."""
    n_active = param_count(cfg)
    if cfg.num_experts:
        dense_experts = cfg.num_experts - cfg.experts_per_tok - (1 if cfg.shared_expert else 0)
        n_active -= len([k for k in cfg.layer_kinds() if k == "moe"]) * max(dense_experts, 0) * 3 * cfg.d_model * cfg.d_ff
    flops = 6.0 * n_active
    # attention score/value FLOPs
    hd = cfg.resolved_head_dim
    for kind in cfg.layer_kinds():
        if kind in ("attn", "moe"):
            ctx = seq_len / 2
        elif kind == "local":
            ctx = min(cfg.window_size or seq_len, seq_len) / 2 + (cfg.window_size or 0) / 2
            ctx = min(ctx, seq_len / 2)
        else:
            continue
        flops += 12.0 * cfg.num_heads * hd * ctx
    return flops
