"""Shared neural-net building blocks (pure JAX, functional).

Parameters are nested dicts of jnp arrays.  Initializers take an explicit
``jax.random`` key.  Everything here is shape-polymorphic over batch/seq and
dtype-polymorphic (params may be f32 for the FL simulation or bf16 for the
production dry-run).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init",
    "rms_norm",
    "layer_norm",
    "softcap",
    "rotary_embedding",
    "apply_rope",
    "gelu",
    "silu",
]


def dense_init(key, in_dim: int, out_shape, scale: float | None = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-ish), matmul weight of shape
    (in_dim, *out_shape)."""
    if scale is None:
        scale = 1.0 / math.sqrt(in_dim)
    shape = (in_dim, *out_shape) if isinstance(out_shape, tuple) else (in_dim, out_shape)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with (1+scale) parameterization (Gemma-style: init scale=0)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rotary_embedding(positions: jnp.ndarray, head_dim: int, theta: float = 10000.0):
    """Return (sin, cos) of shape [..., head_dim/2] for given positions."""
    freqs = 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., hd/2]
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; sin/cos: [..., seq, head_dim/2]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    sin = sin[..., :, None, :]
    cos = cos[..., :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)
