"""Model assembly: decoder-only LMs, enc-dec (Whisper), hybrid/SSM stacks.

Layer stacking: full periods of ``cfg.block_pattern`` are parameter-stacked
and driven by ``lax.scan`` (small HLO — essential for 512-device CPU
compiles); remainder layers are unrolled.  Each scan body is rematerialized
(``jax.checkpoint``) when ``cfg.remat``.

Three entry points per model:
  * ``forward(params, cfg, batch)``          -> logits              (train)
  * ``prefill(params, cfg, batch)``          -> (logits, state)     (inference)
  * ``decode_step(params, cfg, state, tok, pos)`` -> (logits, state)

The decode state is a pytree of ring-buffer KV caches / recurrent states,
stacked over scan groups exactly like the parameters.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import ffn as ffn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import xlstm as xlstm_mod
from .attention import AttnSpec
from .config import ModelConfig
from .ffn import FFNSpec
from .layers import layer_norm, rms_norm, softcap
from .moe import MoESpec
from .rglru import RGLRUSpec
from .xlstm import XLSTMSpec
from repro.sharding.specs import constrain

__all__ = [
    "init_params",
    "forward",
    "lm_loss",
    "prefill",
    "init_decode_state",
    "decode_step",
]


# --------------------------------------------------------------------------
# specs per block kind
# --------------------------------------------------------------------------

def _attn_spec(cfg: ModelConfig, kind: str, causal: bool = True) -> AttnSpec:
    return AttnSpec(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        qk_norm=cfg.qk_norm,
        qkv_bias=cfg.qkv_bias,
        logit_softcap=cfg.attn_softcap,
        window=cfg.window_size if kind in ("local", "moe_local") else None,
        causal=causal,
        rope_theta=cfg.rope_theta,
        use_rope=cfg.pos_embed == "rope",
    )


def _ffn_spec(cfg: ModelConfig) -> FFNSpec:
    return FFNSpec(cfg.d_model, cfg.d_ff, gated=cfg.gated_ffn, activation=cfg.activation)


def _moe_spec(cfg: ModelConfig) -> MoESpec:
    return MoESpec(
        d_model=cfg.d_model,
        num_experts=cfg.num_experts,
        num_experts_per_tok=cfg.experts_per_tok,
        d_ff=cfg.d_ff,
        capacity_factor=cfg.moe_capacity_factor,
        shared_expert=cfg.shared_expert,
    )


def _rglru_spec(cfg: ModelConfig) -> RGLRUSpec:
    return RGLRUSpec(cfg.d_model, cfg.rnn_width or cfg.d_model, cfg.rnn_heads)


def _xlstm_spec(cfg: ModelConfig) -> XLSTMSpec:
    return XLSTMSpec(cfg.d_model, cfg.num_heads, cfg.xlstm_proj_factor,
                     t_block=cfg.attn_q_block)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init_norm(cfg: ModelConfig, dtype):
    if cfg.norm_style == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype), "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.zeros((cfg.d_model,), dtype)}


def _apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm_style == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def _init_block(key, cfg: ModelConfig, kind: str, dtype, causal: bool = True, cross: bool = False):
    keys = jax.random.split(key, 6)
    if kind in ("attn", "local", "moe", "moe_local"):
        p = {
            "ln1": _init_norm(cfg, dtype),
            "attn": attn_mod.init_attention(keys[0], _attn_spec(cfg, kind, causal), dtype),
            "ln2": _init_norm(cfg, dtype),
        }
        if kind in ("moe", "moe_local"):
            p["moe"] = moe_mod.init_moe(keys[1], _moe_spec(cfg), dtype)
        else:
            p["ffn"] = ffn_mod.init_ffn(keys[1], _ffn_spec(cfg), dtype)
        if cross:
            xspec = _attn_spec(cfg, "attn", causal=False)
            p["lnx"] = _init_norm(cfg, dtype)
            p["xattn"] = attn_mod.init_attention(keys[2], xspec, dtype)
        return p
    if kind == "rglru":
        return {
            "ln1": _init_norm(cfg, dtype),
            "rglru": rglru_mod.init_rglru(keys[0], _rglru_spec(cfg), dtype),
            "ln2": _init_norm(cfg, dtype),
            "ffn": ffn_mod.init_ffn(keys[1], _ffn_spec(cfg), dtype),
        }
    if kind == "mlstm":
        return {"ln": _init_norm(cfg, dtype), "cell": xlstm_mod.init_mlstm(keys[0], _xlstm_spec(cfg), dtype)}
    if kind == "slstm":
        return {"ln": _init_norm(cfg, dtype), "cell": xlstm_mod.init_slstm(keys[0], _xlstm_spec(cfg), dtype)}
    raise ValueError(f"unknown block kind {kind}")


def _split_layers(cfg: ModelConfig) -> Tuple[int, List[str]]:
    """(num_full_groups, remainder_kinds)."""
    period = len(cfg.block_pattern)
    if not cfg.scan_layers:
        return 0, list(cfg.layer_kinds())
    g = cfg.num_layers // period
    rem = list(cfg.layer_kinds()[g * period :])
    return g, rem


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    params["embed"] = (
        jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02
    ).astype(dtype)
    if cfg.pos_embed == "learned":
        params["pos_embed"] = (
            jax.random.normal(keys[1], (cfg.max_position, cfg.d_model), jnp.float32) * 0.01
        ).astype(dtype)
    g, rem = _split_layers(cfg)
    cross = cfg.encoder_layers > 0
    if g > 0:
        def one_group(k):
            ks = jax.random.split(k, len(cfg.block_pattern))
            return {
                f"s{j}": _init_block(ks[j], cfg, kind, dtype, cross=cross)
                for j, kind in enumerate(cfg.block_pattern)
            }
        gkeys = jax.random.split(keys[2], g)
        groups = [one_group(gkeys[i]) for i in range(g)]
        params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *groups)
    if rem:
        rkeys = jax.random.split(keys[3], len(rem))
        params["rem"] = [
            _init_block(rkeys[i], cfg, kind, dtype, cross=cross) for i, kind in enumerate(rem)
        ]
    params["final_norm"] = _init_norm(cfg, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[4], (cfg.d_model, cfg.vocab_size), jnp.float32) * 0.02
        ).astype(dtype)
    if cfg.encoder_layers:
        ekeys = jax.random.split(keys[5], cfg.encoder_layers + 2)
        params["enc_blocks"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[
                {"s0": _init_block(ekeys[i], cfg, "attn", dtype, causal=False)}
                for i in range(cfg.encoder_layers)
            ],
        )
        params["enc_norm"] = _init_norm(cfg, dtype)
        params["enc_pos"] = (
            jax.random.normal(ekeys[-1], (cfg.max_position, cfg.d_model), jnp.float32) * 0.01
        ).astype(dtype)
    return params


# --------------------------------------------------------------------------
# block application (full-sequence)
# --------------------------------------------------------------------------

def _block_fwd(cfg: ModelConfig, kind: str, p, x, enc_out, collect_cache: bool,
               cache_len: int = 0):
    """Returns (x, aux_loss, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind in ("attn", "local", "moe", "moe_local"):
        spec = _attn_spec(cfg, kind)
        h, (k, v) = attn_mod.attention_fwd(
            p["attn"], spec, _apply_norm(cfg, p["ln1"], x), q_block=cfg.attn_q_block
        )
        x = x + h
        if "xattn" in p:
            xh, _ = attn_mod.attention_fwd(
                p["xattn"], _attn_spec(cfg, "attn", causal=False),
                _apply_norm(cfg, p["lnx"], x), xkv=enc_out, q_block=cfg.attn_q_block,
            )
            x = x + xh
        if kind in ("moe", "moe_local"):
            h, aux = moe_mod.moe_fwd(p["moe"], _moe_spec(cfg), _apply_norm(cfg, p["ln2"], x))
        else:
            h = ffn_mod.ffn_fwd(p["ffn"], _ffn_spec(cfg), _apply_norm(cfg, p["ln2"], x))
        x = x + h
        if collect_cache:
            cache = _ringify(cfg, kind, k, v, p, enc_out, cache_len)
    elif kind == "rglru":
        h, state = rglru_mod.rglru_fwd(p["rglru"], _rglru_spec(cfg), _apply_norm(cfg, p["ln1"], x))
        x = x + h
        h = ffn_mod.ffn_fwd(p["ffn"], _ffn_spec(cfg), _apply_norm(cfg, p["ln2"], x))
        x = x + h
        if collect_cache:
            cache = state
    elif kind == "mlstm":
        h, state = xlstm_mod.mlstm_fwd(p["cell"], _xlstm_spec(cfg), _apply_norm(cfg, p["ln"], x))
        x = x + h
        if collect_cache:
            cache = state
    elif kind == "slstm":
        h, state = xlstm_mod.slstm_fwd(p["cell"], _xlstm_spec(cfg), _apply_norm(cfg, p["ln"], x))
        x = x + h
        if collect_cache:
            cache = state
    else:
        raise ValueError(kind)
    return x, aux, cache


def _ringify(cfg: ModelConfig, kind: str, k, v, p, enc_out, cache_len: int):
    """Convert prefill K/V into the ring-buffer decode cache.

    ``cache_len`` is the total capacity (prefill length + decode headroom);
    windowed layers clamp it to the window so the ring rotates.
    """
    spec = _attn_spec(cfg, kind)
    b, s = k.shape[0], k.shape[1]
    L = min(cache_len, spec.window) if spec.window is not None else cache_len
    if s >= L:
        # keep the last L entries; their slots are pos % L (ring semantics)
        k_tail, v_tail = k[:, -L:], v[:, -L:]
        tail_pos = jnp.arange(s - L, s, dtype=jnp.int32)
        slots = jnp.mod(tail_pos, L)
        order = jnp.argsort(slots)
        kk = jnp.take(k_tail, order, axis=1)
        vv = jnp.take(v_tail, order, axis=1)
        pos = jnp.broadcast_to(jnp.take(tail_pos, order)[None], (b, L))
    else:
        pad = L - s
        kk = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pos = jnp.concatenate(
            [jnp.arange(s, dtype=jnp.int32), jnp.full((pad,), -1, jnp.int32)]
        )
        pos = jnp.broadcast_to(pos[None], (b, L))
    cache = {"k": kk, "v": vv, "pos": pos}
    if "xattn" in p:
        xs = _attn_spec(cfg, "attn", causal=False)
        # static encoder K/V for cross attention
        kx = jnp.einsum("btd,dhk->bthk", enc_out, p["xattn"]["wk"])
        vx = jnp.einsum("btd,dhk->bthk", enc_out, p["xattn"]["wv"])
        cache = {"self": cache, "cross_k": kx, "cross_v": vx}
    return cache


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------

def _embed_inputs(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.num_prefix_embeds and "prefix_embeds" in batch:
        x = jnp.concatenate([batch["prefix_embeds"].astype(x.dtype), x], axis=1)
    if cfg.pos_embed == "learned":
        s = x.shape[1]
        x = x + params["pos_embed"][:s][None]
    return constrain(x, [(0, "batch")])


def _run_encoder(params, cfg: ModelConfig, enc_embeds):
    x = enc_embeds.astype(_dtype(cfg))
    s = x.shape[1]
    x = x + params["enc_pos"][:s][None]

    def body(carry, gp):
        h, _, _ = _block_fwd(cfg, "attn", gp["s0"], constrain(carry, [(0, "batch")]), None, False)
        return constrain(h, [(0, "batch")]), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return _apply_norm(cfg, params["enc_norm"], x)


def _stack_fwd(params, cfg: ModelConfig, x, enc_out, collect_cache: bool,
               cache_len: int = 0):
    """Run all layers; returns (x, total_aux, caches dict)."""
    g, rem = _split_layers(cfg)
    caches: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)
    if g > 0:
        boundary = [(0, "batch"), (1, "model")] if cfg.seq_shard_activations else [(0, "batch")]

        def body(carry, gp):
            h, aux_acc = carry
            h = constrain(h, boundary)
            group_caches = {}
            for j, kind in enumerate(cfg.block_pattern):
                h, aux, cache = _block_fwd(cfg, kind, gp[f"s{j}"], h, enc_out, collect_cache, cache_len)
                aux_acc = aux_acc + aux
                if collect_cache:
                    group_caches[f"s{j}"] = cache
            h = constrain(h, boundary)
            return (h, aux_acc), (group_caches if collect_cache else None)

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_total), scan_caches = jax.lax.scan(body, (x, aux_total), params["blocks"])
        if collect_cache:
            caches["blocks"] = scan_caches
    for i, kind in enumerate(rem):
        fwd = _block_fwd
        if cfg.remat and not collect_cache:
            fwd = jax.checkpoint(_block_fwd, static_argnums=(0, 1, 5, 6))
        x, aux, cache = fwd(cfg, kind, params["rem"][i], x, enc_out, collect_cache, cache_len)
        aux_total = aux_total + aux
        if collect_cache:
            caches.setdefault("rem", []).append(cache)
    return x, aux_total, caches


def _logits(params, cfg: ModelConfig, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", constrain(x, [(0, "batch")]), head)
    logits = constrain(logits, [(0, "batch"), (2, "model")])
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if cfg.vocab_size_real is not None and cfg.vocab_size_real < cfg.vocab_size:
        # vocab was padded up for model-axis divisibility; mask the padding
        mask = jnp.arange(cfg.vocab_size) >= cfg.vocab_size_real
        logits = jnp.where(mask, jnp.float32(-1e30), logits)
    return logits


def forward(params, cfg: ModelConfig, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training forward. Returns (logits [b, s_text, V], aux_loss)."""
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _run_encoder(params, cfg, batch["enc_embeds"])
    x = _embed_inputs(params, cfg, batch)
    x, aux, _ = _stack_fwd(params, cfg, x, enc_out, collect_cache=False)
    x = _apply_norm(cfg, params["final_norm"], x)
    if cfg.num_prefix_embeds and "prefix_embeds" in batch:
        x = x[:, batch["prefix_embeds"].shape[1] :]
    return _logits(params, cfg, x), aux


def lm_loss(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    """Next-token cross entropy (+ MoE aux). labels = tokens shifted left."""
    logits, aux = forward(params, cfg, batch)
    logits = logits[:, :-1]
    labels = batch["tokens"][:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: a gather along the
    # model-sharded vocab axis would force an all-gather of logp (16 GiB/dev
    # at 92k vocab); the einsum partitions cleanly.
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logp.dtype)
    ll = jnp.einsum("bsv,bsv->bs", logp, onehot)
    mask = batch.get("loss_mask")
    if mask is not None:
        mask = mask[:, 1:]
        ce = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    else:
        ce = -ll.mean()
    return ce + aux


# --------------------------------------------------------------------------
# inference: prefill + single-token decode
# --------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch, max_len: int | None = None):
    """Returns (last-position logits [b, V], decode state).

    ``max_len``: total KV-cache capacity (prefill length + decode headroom);
    defaults to 2x the prompt length.
    """
    enc_out = None
    if cfg.encoder_layers:
        enc_out = _run_encoder(params, cfg, batch["enc_embeds"])
    x = _embed_inputs(params, cfg, batch)
    s_total = x.shape[1]
    if max_len is None:
        max_len = 2 * s_total
    x, _, caches = _stack_fwd(params, cfg, x, enc_out, collect_cache=True,
                              cache_len=max_len)
    x = _apply_norm(cfg, params["final_norm"], x)
    logits = _logits(params, cfg, x[:, -1:])[:, 0]
    state = {"caches": caches, "pos": jnp.asarray(s_total, jnp.int32)}
    return logits, state


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int, dtype, enc_len: int):
    if kind in ("attn", "local", "moe", "moe_local"):
        spec = _attn_spec(cfg, kind)
        c = attn_mod.init_cache(spec, batch, cache_len, dtype)
        if cfg.encoder_layers:
            KV, hd = spec.num_kv_heads, spec.head_dim
            c = {
                "self": c,
                "cross_k": jnp.zeros((batch, enc_len, KV, hd), dtype),
                "cross_v": jnp.zeros((batch, enc_len, KV, hd), dtype),
            }
        return c
    if kind == "rglru":
        return rglru_mod.init_rglru_state(_rglru_spec(cfg), batch, dtype)
    if kind == "mlstm":
        return xlstm_mod.init_mlstm_state(_xlstm_spec(cfg), batch)
    if kind == "slstm":
        return xlstm_mod.init_slstm_state(_xlstm_spec(cfg), batch)
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int, enc_len: int = 0):
    dtype = _dtype(cfg)
    g, rem = _split_layers(cfg)
    caches: Dict[str, Any] = {}
    if g > 0:
        def one(kind):
            return _init_block_cache(cfg, kind, batch, cache_len, dtype, enc_len)
        group = {f"s{j}": one(kind) for j, kind in enumerate(cfg.block_pattern)}
        caches["blocks"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (g, *x.shape)).copy() if g else x, group
        )
    if rem:
        caches["rem"] = [
            _init_block_cache(cfg, kind, batch, cache_len, dtype, enc_len) for kind in rem
        ]
    return {"caches": caches, "pos": jnp.asarray(0, jnp.int32)}


def _block_decode(cfg: ModelConfig, kind: str, p, x, cache, position):
    if kind in ("attn", "local", "moe", "moe_local"):
        spec = _attn_spec(cfg, kind)
        inner = cache["self"] if "cross_k" in cache else cache
        h, new_inner = attn_mod.attention_decode(
            p["attn"], spec, _apply_norm(cfg, p["ln1"], x), inner, position
        )
        x = x + h
        if "cross_k" in cache:
            xh = attn_mod.cross_attention_decode(
                p["xattn"], _attn_spec(cfg, "attn", causal=False),
                _apply_norm(cfg, p["lnx"], x), cache["cross_k"], cache["cross_v"],
            )
            x = x + xh
            new_cache = {"self": new_inner, "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
        else:
            new_cache = new_inner
        if kind in ("moe", "moe_local"):
            h, _ = moe_mod.moe_fwd(p["moe"], _moe_spec(cfg), _apply_norm(cfg, p["ln2"], x))
        else:
            h = ffn_mod.ffn_fwd(p["ffn"], _ffn_spec(cfg), _apply_norm(cfg, p["ln2"], x))
        return x + h, new_cache
    if kind == "rglru":
        h, state = rglru_mod.rglru_decode(p["rglru"], _rglru_spec(cfg), _apply_norm(cfg, p["ln1"], x), cache)
        x = x + h
        h = ffn_mod.ffn_fwd(p["ffn"], _ffn_spec(cfg), _apply_norm(cfg, p["ln2"], x))
        return x + h, state
    if kind == "mlstm":
        h, state = xlstm_mod.mlstm_decode(p["cell"], _xlstm_spec(cfg), _apply_norm(cfg, p["ln"], x), cache)
        return x + h, state
    if kind == "slstm":
        h, state = xlstm_mod.slstm_decode(p["cell"], _xlstm_spec(cfg), _apply_norm(cfg, p["ln"], x), cache)
        return x + h, state
    raise ValueError(kind)


def decode_step(params, cfg: ModelConfig, state, token: jnp.ndarray):
    """One decode step. token: [b] int32. Returns (logits [b,V], new state)."""
    position = state["pos"]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if cfg.pos_embed == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], position, 1)[None]
    g, rem = _split_layers(cfg)
    caches = state["caches"]
    new_caches: Dict[str, Any] = {}
    if g > 0:
        def body(carry, xs):
            h = constrain(carry, [(0, "batch")])
            gp, gc = xs
            new_gc = {}
            for j, kind in enumerate(cfg.block_pattern):
                h, new_gc[f"s{j}"] = _block_decode(cfg, kind, gp[f"s{j}"], h, gc[f"s{j}"], position)
            return h, new_gc

        x, nb = jax.lax.scan(body, x, (params["blocks"], caches["blocks"]))
        new_caches["blocks"] = nb
    if rem:
        new_caches["rem"] = []
        for i, kind in enumerate(rem):
            x, nc = _block_decode(cfg, kind, params["rem"][i], x, caches["rem"][i], position)
            new_caches["rem"].append(nc)
    x = _apply_norm(cfg, params["final_norm"], x)
    logits = _logits(params, cfg, x)[:, 0]
    return logits, {"caches": new_caches, "pos": position + 1}
