"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU (arXiv:2402.19427).

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t)          # recurrence gate
    i_t = sigmoid(W_x x_t)          # input gate
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` over the sequence — a
log-depth combinator tree, deliberately NOT a ``while`` loop so that XLA
cost_analysis attributes the full sequence cost (see DESIGN.md roofline
notes).  Decode carries the [b, dr] state one step.  TPU adaptation: the
original GPU implementation uses a custom linear-scan kernel; our Pallas
``rg_lru_scan`` kernel covers the sequential-block variant, the jnp path here
is the oracle-equivalent associative form.

Gates are block-diagonal over heads as in Griffin.  Prunable units are
*recurrent head groups* (dr/heads channels each).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, gelu

__all__ = ["RGLRUSpec", "init_rglru", "rglru_fwd", "rglru_decode", "init_rglru_state"]

_C = 8.0  # Griffin's fixed temperature on the recurrence gate


@dataclasses.dataclass(frozen=True)
class RGLRUSpec:
    d_model: int
    d_rnn: int               # lru width
    num_heads: int           # block-diagonal gate heads
    conv_width: int = 4


def init_rglru(key, spec: RGLRUSpec, dtype=jnp.float32):
    ky, kx, kc, ka, kb, ko, kl = jax.random.split(key, 7)
    D, R, H = spec.d_model, spec.d_rnn, spec.num_heads
    hw = R // H
    # Lambda init so that a = exp(-c*softplus(L)) spreads over (0.9, 0.999)
    u = jax.random.uniform(kl, (R,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.exp(-jnp.log(u) / (2 * _C)) - 1.0)  # softplus^-1(-log(u)/(2c))
    return {
        "w_y": dense_init(ky, D, R, dtype=dtype),            # gate branch in
        "w_x": dense_init(kx, D, R, dtype=dtype),            # recurrent branch in
        "conv": (jax.random.normal(kc, (spec.conv_width, R), jnp.float32) * 0.02).astype(dtype),
        "gate_a": (jax.random.normal(ka, (H, hw, hw), jnp.float32) / math.sqrt(hw)).astype(dtype),
        "gate_x": (jax.random.normal(kb, (H, hw, hw), jnp.float32) / math.sqrt(hw)).astype(dtype),
        "lam": lam.astype(jnp.float32),                      # keep f32 (stability)
        "w_out": dense_init(ko, R, D, dtype=dtype),
    }


def _causal_conv(x, kernel):
    """Depthwise causal conv over seq: x [b,s,r], kernel [w,r]."""
    w = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * kernel[i][None, None, :] for i in range(w)
    )
    return out


def _gates(x, params, spec: RGLRUSpec):
    """Block-diagonal gate projections; x [.., s, r] -> (r_t, i_t)."""
    H = spec.num_heads
    hw = x.shape[-1] // H
    xh = x.reshape(*x.shape[:-1], H, hw)
    r = jax.nn.sigmoid(jnp.einsum("...hi,hij->...hj", xh, params["gate_a"]))
    i = jax.nn.sigmoid(jnp.einsum("...hi,hij->...hj", xh, params["gate_x"]))
    return r.reshape(x.shape), i.reshape(x.shape)


def _lru_coeffs(params, x_branch, spec: RGLRUSpec):
    r, i = _gates(x_branch, params, spec)
    log_a = -_C * jax.nn.softplus(params["lam"]) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) with numerical floor
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * (i.astype(jnp.float32) * x_branch.astype(jnp.float32))
    return a, b


def init_rglru_state(spec: RGLRUSpec, batch: int, dtype=jnp.float32):
    return {
        "h": jnp.zeros((batch, spec.d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_width - 1, spec.d_rnn), dtype),
    }


def rglru_fwd(params, spec: RGLRUSpec, x: jnp.ndarray):
    """Full-sequence forward. x [b,s,d] -> ([b,s,d], final_state)."""
    y = gelu(jnp.einsum("bsd,dr->bsr", x, params["w_y"]))
    xr = jnp.einsum("bsd,dr->bsr", x, params["w_x"])
    conv_tail = xr[:, -(spec.conv_width - 1) :, :]
    xr = _causal_conv(xr, params["conv"])
    a, b = _lru_coeffs(params, xr, spec)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = jnp.einsum("bsr,rd->bsd", (h * y.astype(jnp.float32)).astype(x.dtype), params["w_out"])
    state = {"h": h[:, -1, :], "conv": conv_tail}
    return out, state


def rglru_decode(params, spec: RGLRUSpec, x: jnp.ndarray, state):
    """One-token decode. x [b,1,d] -> ([b,1,d], new_state)."""
    y = gelu(jnp.einsum("bsd,dr->bsr", x, params["w_y"]))
    xr = jnp.einsum("bsd,dr->bsr", x, params["w_x"])          # [b,1,r]
    window = jnp.concatenate([state["conv"], xr], axis=1)     # [b,w,r]
    kernel = params["conv"]
    xc = jnp.einsum("bwr,wr->br", window, kernel)[:, None, :]
    a, b = _lru_coeffs(params, xc, spec)
    h = a[:, 0] * state["h"] + b[:, 0]
    out = jnp.einsum("bsr,rd->bsd", (h[:, None] * y.astype(jnp.float32)).astype(x.dtype), params["w_out"])
    return out, {"h": h, "conv": window[:, 1:, :]}
