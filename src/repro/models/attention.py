"""Grouped-query attention with the feature set of the assigned pool.

Supports: GQA (num_kv_heads <= num_heads), rotary embeddings, qk-norm
(Qwen-3), QKV bias (Qwen-1.5), attention-logit softcap (Gemma-2), causal /
bidirectional / sliding-window masks, cross-attention (Whisper), and
single-token decode against a KV cache (full or ring-buffer window cache).

Two execution paths: a pure-jnp path (works everywhere; used by the CPU
dry-run + smoke tests) and the Pallas flash kernel path
(``repro.kernels.ops.flash_attention``) for TPU training/prefill.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init, rms_norm, rotary_embedding, softcap
from repro.sharding.specs import constrain

__all__ = ["AttnSpec", "init_attention", "attention_fwd", "attention_decode"]


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    logit_softcap: Optional[float] = None
    window: Optional[int] = None        # sliding-window size (None = full)
    causal: bool = True                 # False for encoder / cross-attn
    rope_theta: float = 10000.0
    use_rope: bool = True


def init_attention(key, spec: AttnSpec, dtype=jnp.float32):
    kq, kk, kv, ko, kb = jax.random.split(key, 5)
    H, KV, hd, D = spec.num_heads, spec.num_kv_heads, spec.head_dim, spec.d_model
    p = {
        "wq": dense_init(kq, D, (H, hd), dtype=dtype),
        "wk": dense_init(kk, D, (KV, hd), dtype=dtype),
        "wv": dense_init(kv, D, (KV, hd), dtype=dtype),
        "wo": dense_init(ko, H * hd, D, scale=1.0 / math.sqrt(H * hd), dtype=dtype).reshape(H, hd, D),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    if spec.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _project_qkv(params, spec: AttnSpec, x, xkv, q_positions, kv_positions):
    q = constrain(jnp.einsum("bsd,dhk->bshk", x, params["wq"]), [(0, "batch"), (2, "model")])
    k = constrain(jnp.einsum("bsd,dhk->bshk", xkv, params["wk"]), [(0, "batch"), (2, "model")])
    v = constrain(jnp.einsum("bsd,dhk->bshk", xkv, params["wv"]), [(0, "batch"), (2, "model")])
    if spec.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if spec.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if spec.use_rope:
        sin_q, cos_q = rotary_embedding(q_positions, spec.head_dim, spec.rope_theta)
        sin_k, cos_k = rotary_embedding(kv_positions, spec.head_dim, spec.rope_theta)
        q = apply_rope(q, sin_q, cos_q)
        k = apply_rope(k, sin_k, cos_k)
    return q, k, v


def _mask_bias(spec: AttnSpec, q_pos, kv_pos, dtype):
    """[q_len, kv_len] additive mask (0 keep / -inf drop)."""
    neg = jnp.finfo(jnp.float32).min
    qp = q_pos[:, None]
    kp = kv_pos[None, :]
    keep = jnp.ones(qp.shape[:1] + kp.shape[1:], dtype=bool)
    if spec.causal:
        keep = keep & (kp <= qp)
    if spec.window is not None:
        keep = keep & (kp > qp - spec.window)
    return jnp.where(keep, 0.0, neg)


def _repeat_kv(x, rep):
    # [b,t,kv,hd] -> [b,t,kv*rep,hd]; keeps scores head-major so the TP axis
    # shards all H query heads (kv alone rarely divides the model axis).
    if rep == 1:
        return x
    b, t, kv, hd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, t, kv, rep, hd)).reshape(b, t, kv * rep, hd)


def _sdpa(spec: AttnSpec, q, k, v, bias):
    """q:[b,s,h,hd] k/v:[b,t,kv,hd] bias:[s,t] -> [b,s,h,hd]."""
    b, s, H, hd = q.shape
    rep = H // k.shape[2]
    k = _repeat_kv(k.astype(jnp.float32), rep)
    v = _repeat_kv(v.astype(jnp.float32), rep)
    scores = jnp.einsum("bshk,bthk->bhst", q.astype(jnp.float32), k)
    scores = scores / math.sqrt(hd)
    scores = softcap(scores, spec.logit_softcap)
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthk->bshk", probs, v)
    return constrain(out, [(0, "batch"), (2, "model")])


def attention_fwd(
    params,
    spec: AttnSpec,
    x: jnp.ndarray,
    *,
    xkv: Optional[jnp.ndarray] = None,
    q_offset: int = 0,
    q_block: Optional[int] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence attention (training / prefill).

    ``q_block``: process queries in blocks of this size (scores stay
    [b, qb, ., s] instead of [b, s, ., s]) — the memory-safe path for long
    sequences on the jnp backend; the Pallas flash kernel is the TPU
    fast path.  Returns (output [b,s,d], (k_cache, v_cache)) — caches are the
    raw post-rope K/V, reusable by ``attention_decode``.
    """
    self_attn = xkv is None
    xkv = x if self_attn else xkv
    b, s, _ = x.shape
    t = xkv.shape[1]
    q_pos = jnp.arange(s) + q_offset
    kv_pos = jnp.arange(t) + (q_offset if self_attn else 0)
    q, k, v = _project_qkv(params, spec, x, xkv, q_pos, kv_pos)
    if q_block is None or s <= q_block or s % q_block != 0:
        bias = _mask_bias(spec, q_pos, kv_pos, x.dtype)
        out = _sdpa(spec, q, k, v, bias)
    else:
        nq = s // q_block
        qb = jnp.moveaxis(q.reshape(b, nq, q_block, *q.shape[2:]), 1, 0)
        pb = q_pos.reshape(nq, q_block)

        def body(_, xs):
            q_i, pos_i = xs
            bias_i = _mask_bias(spec, pos_i, kv_pos, x.dtype)
            return None, _sdpa(spec, q_i, k, v, bias_i)

        _, ob = jax.lax.scan(body, None, (qb, pb))
        out = jnp.moveaxis(ob, 0, 1).reshape(b, s, *ob.shape[3:])
    out = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])
    return out, (k, v)


def init_cache(spec: AttnSpec, batch: int, max_len: int, dtype):
    """Ring-buffer KV cache. For windowed layers max_len = window."""
    if spec.window is not None:
        max_len = min(max_len, spec.window)
    shape = (batch, max_len, spec.num_kv_heads, spec.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        # absolute position of each slot's token; -1 = empty
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def attention_decode(
    params,
    spec: AttnSpec,
    x: jnp.ndarray,           # [b, 1, d]
    cache,                    # ring-buffer dict from init_cache
    position: jnp.ndarray,    # scalar int32: absolute position of this token
):
    """One-token decode; returns (out [b,1,d], new_cache)."""
    b = x.shape[0]
    q_pos = jnp.asarray(position)[None]
    q, k_new, v_new = _project_qkv(params, spec, x, x, q_pos, q_pos)
    L = cache["k"].shape[1]
    slot = jnp.mod(position, L)
    # ring-buffer write at `slot`
    k = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(
        cache["pos"], jnp.full((b, 1), position, jnp.int32), (0, slot)
    )
    new_cache = {"k": k, "v": v, "pos": pos}
    # bias from stored absolute positions: keep pos>=0, causal, window
    neg = jnp.finfo(jnp.float32).min
    keep = pos >= 0
    keep = keep & (pos <= position)
    if spec.window is not None:
        keep = keep & (pos > position - spec.window)
    bias = jnp.where(keep, 0.0, neg)  # [b, L]
    H, KV, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    rep = H // KV
    kr = _repeat_kv(k.astype(jnp.float32), rep)
    vr = _repeat_kv(v.astype(jnp.float32), rep)
    scores = jnp.einsum("bshk,bthk->bhst", q.astype(jnp.float32), kr)
    scores = scores / math.sqrt(hd)
    scores = softcap(scores, spec.logit_softcap)
    scores = scores + bias[:, None, None, :]  # broadcast over h,s
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthk->bshk", probs, vr)
    out = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])
    return out, new_cache


def cross_attention_decode(params, spec: AttnSpec, x, enc_k, enc_v):
    """Decode-time cross-attention: static encoder K/V, no cache update."""
    b = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if spec.qkv_bias:
        q = q + params["bq"]
    if spec.qk_norm:
        q = rms_norm(q, params["q_norm"])
    H, KV, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    rep = H // KV
    kr = _repeat_kv(enc_k.astype(jnp.float32), rep)
    vr = _repeat_kv(enc_v.astype(jnp.float32), rep)
    scores = jnp.einsum("bshk,bthk->bhst", q.astype(jnp.float32), kr) / math.sqrt(hd)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthk->bshk", probs, vr)
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])
