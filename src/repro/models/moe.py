"""Mixture-of-Experts FFN: sort-based (dropping) dispatch, expert-parallel.

Two execution paths:

* **Local path** (no mesh): plain sort-based dispatch — tokens routed top-k,
  sorted by expert, packed into a static ``[E, C, D]`` buffer, batched expert
  einsum, combined with router weights.  FLOPs are O(T * k * cf * D * F).

* **Expert-parallel path** (under a mesh): ``shard_map`` over (data, model).
  Activations are sharded over the data axis and replicated over the model
  axis; experts are sharded over the model axis.  Each device runs the local
  sort-based dispatch for its (token-shard x expert-shard) block and a single
  ``psum`` over the model axis combines expert contributions.  The global
  sort/scatter that defeats GSPMD (142 GiB/device of replicated dispatch
  buffers when left to auto-sharding — see EXPERIMENTS.md §Perf) never
  appears: every sort is device-local.

Experts are a prunable AdaptCL unit (whole-expert pruning); the router
renormalizes over retained experts automatically because pruned experts do
not exist in the reconfigured weights.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.specs import current_mesh

from .layers import dense_init, silu

__all__ = ["MoESpec", "init_moe", "moe_fwd", "capacity"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    num_experts: int
    num_experts_per_tok: int
    d_ff: int                      # per-expert hidden size
    capacity_factor: float = 1.25
    shared_expert: bool = False    # Llama-4 style always-on expert
    shared_d_ff: Optional[int] = None
    router_aux_weight: float = 0.01


def capacity(spec: MoESpec, num_tokens: int) -> int:
    c = int(
        math.ceil(num_tokens * spec.num_experts_per_tok * spec.capacity_factor / spec.num_experts)
    )
    return max(8, ((c + 7) // 8) * 8)


def init_moe(key, spec: MoESpec, dtype=jnp.float32):
    kr, kg, ku, kd, ksg, ksu, ksd = jax.random.split(key, 7)
    E, D, F = spec.num_experts, spec.d_model, spec.d_ff
    p = {
        "w_router": dense_init(kr, D, E, dtype=jnp.float32),  # router in f32
        "w_gate": (dense_init(kg, D, (E, F), dtype=dtype)).transpose(1, 0, 2),  # [E,D,F]
        "w_up": (dense_init(ku, D, (E, F), dtype=dtype)).transpose(1, 0, 2),
        "w_down": (dense_init(kd, F, (E, D), dtype=dtype)).transpose(1, 0, 2),  # [E,F,D]
    }
    if spec.shared_expert:
        SF = spec.shared_d_ff or F
        p["ws_gate"] = dense_init(ksg, D, SF, dtype=dtype)
        p["ws_up"] = dense_init(ksu, D, SF, dtype=dtype)
        p["ws_down"] = dense_init(ksd, SF, D, dtype=dtype)
    return p


def _dispatch_compute_combine(params, spec: MoESpec, xf, probs, e_lo, n_local: int):
    """Sort-based dispatch restricted to experts [e_lo, e_lo + n_local).

    ``n_local`` is static (shapes depend on it); ``e_lo`` may be traced
    (it is ``axis_index * E_loc`` on the expert-parallel path).
    xf: [T, D] local tokens.  probs: [T, E_total] router probabilities.
    Returns (out [T, D], counts [E_total] local routing counts).
    """
    T, D = xf.shape
    k = spec.num_experts_per_tok
    E_here = n_local
    C = capacity(spec, T)

    gate, choice = jax.lax.top_k(probs, k)                     # [T,k] global ids
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    N = T * k
    flat_e = choice.reshape(N)
    counts_all = jnp.zeros((probs.shape[1],), jnp.int32).at[flat_e].add(1)

    local = (flat_e >= e_lo) & (flat_e < e_lo + E_here)
    loc_e = jnp.where(local, flat_e - e_lo, E_here)            # E_here = overflow bucket
    sort_idx = jnp.argsort(loc_e, stable=True)
    sorted_e = loc_e[sort_idx]
    counts = jnp.zeros((E_here + 1,), jnp.int32).at[loc_e].add(1)
    offsets = jnp.cumsum(counts) - counts
    pos_in_exp = jnp.arange(N, dtype=jnp.int32) - offsets[sorted_e]
    keep = (pos_in_exp < C) & (sorted_e < E_here)
    token_of = sort_idx // k
    dest = jnp.where(keep, sorted_e * C + jnp.clip(pos_in_exp, 0, C - 1), E_here * C)
    buf = (
        jnp.zeros((E_here * C + 1, D), xf.dtype)
        .at[dest]
        .add(xf[token_of] * keep[:, None].astype(xf.dtype))
    )[:-1].reshape(E_here, C, D)

    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).reshape(E_here * C, D)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((1, D), out_buf.dtype)], axis=0)

    gathered = out_buf[dest] * keep[:, None].astype(xf.dtype)  # [N,D]
    w = gate.reshape(N)[sort_idx].astype(xf.dtype)
    out = jnp.zeros((T, D), xf.dtype).at[token_of].add(gathered * w[:, None])
    return out, counts_all


def _moe_local(params, spec: MoESpec, x):
    b, s, D = x.shape
    T = b * s
    xf = x.reshape(T, D)
    E = params["w_gate"].shape[0]
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), params["w_router"][:, :E])
    probs = jax.nn.softmax(logits, axis=-1)
    out, counts = _dispatch_compute_combine(params, spec, xf, probs, 0, E)
    if spec.shared_expert:
        sh = silu(jnp.einsum("td,df->tf", xf, params["ws_gate"])) * jnp.einsum(
            "td,df->tf", xf, params["ws_up"]
        )
        out = out + jnp.einsum("tf,fd->td", sh, params["ws_down"])
    frac = counts.astype(jnp.float32) / jnp.maximum(T * spec.num_experts_per_tok, 1)
    aux = spec.router_aux_weight * E * jnp.sum(frac * probs.mean(axis=0))
    return out.reshape(b, s, D), aux


def moe_fwd(params, spec: MoESpec, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output [b,s,d], router load-balance aux loss scalar)."""
    mesh = current_mesh()
    E = params["w_gate"].shape[0]
    n_model = 0 if mesh is None else mesh.shape.get("model", 0)
    if not n_model or E % n_model != 0 or n_model == 1:
        return _moe_local(params, spec, x)

    ba = ("pod", "data") if "pod" in mesh.shape else ("data",)
    n_ba = 1
    for a in ba:
        n_ba *= mesh.shape[a]
    if x.shape[0] % n_ba != 0:
        ba = ()  # decode at batch 1 (long_500k): replicate tokens over data
    E_loc = E // n_model

    def inner(wr, wg, wu, wd, shared, xx):
        b, s, D = xx.shape
        T = b * s
        xf = xx.reshape(T, D)
        m = jax.lax.axis_index("model")
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), wr[:, :E])
        probs = jax.nn.softmax(logits, axis=-1)
        lo = m * E_loc
        lp = {"w_gate": wg, "w_up": wu, "w_down": wd}
        out, counts = _dispatch_compute_combine(lp, spec, xf, probs, lo, E_loc)
        if shared is not None:
            sg, su, sd = shared
            sh = silu(jnp.einsum("td,df->tf", xf, sg)) * jnp.einsum("td,df->tf", xf, su)
            out = out + jnp.einsum("tf,fd->td", sh, sd)
        out = jax.lax.psum(out, "model")
        frac = counts.astype(jnp.float32) / jnp.maximum(T * spec.num_experts_per_tok, 1)
        aux = spec.router_aux_weight * E * jnp.sum(frac * probs.mean(axis=0))
        for ax in (*ba, "model"):
            aux = jax.lax.pmean(aux, ax)
        return out.reshape(b, s, D), aux

    shared = None
    shared_specs = None
    if spec.shared_expert:
        shared = (params["ws_gate"], params["ws_up"], params["ws_down"])
        shared_specs = (P(None, "model"), P(None, "model"), P("model", None))
    out, aux = jax.shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(), P("model"), P("model"), P("model"), shared_specs,
                  P(ba, None, None) if ba else P(None, None, None)),
        out_specs=(P(ba, None, None) if ba else P(None, None, None), P()),
        check_vma=False,
    )(params["w_router"], params["w_gate"], params["w_up"], params["w_down"], shared, x)
    return out, aux
