"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM variant.

mLSTM: trained/prefilled in its *parallel form* (decay-masked attention-like
quadratic form, the form the official implementation uses for moderate
sequence lengths), decoded in its *recurrent form* with O(1) state
``(C [dk,dv], n [dk], m [])`` per head — which is what makes `long_500k`
decode sub-quadratic for this architecture.

sLSTM: implemented in its gate-input-only (associative) variant so the whole
model lowers without sequential while-loops (roofline accounting, see
DESIGN.md); the original's hidden-to-gate recurrent connections are a
documented deviation (DESIGN.md §8).

Prunable units: heads (all projections are head-partitioned).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

__all__ = [
    "XLSTMSpec",
    "init_mlstm", "mlstm_fwd", "mlstm_decode", "init_mlstm_state",
    "init_slstm", "slstm_fwd", "slstm_decode", "init_slstm_state",
]

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class XLSTMSpec:
    d_model: int
    num_heads: int
    proj_factor: float = 2.0     # up-projection factor (mLSTM block)
    t_block: "int | None" = None  # row-block size for the parallel form

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.num_heads


# --------------------------- mLSTM ------------------------------------------

def init_mlstm(key, spec: XLSTMSpec, dtype=jnp.float32):
    ku, kq, kk, kv, ki, kf, ko, kd, kg = jax.random.split(key, 9)
    D, DI, H, hd = spec.d_model, spec.d_inner, spec.num_heads, spec.head_dim
    return {
        "w_up": dense_init(ku, D, DI, dtype=dtype),
        "w_gate": dense_init(kg, D, DI, dtype=dtype),
        "wq": dense_init(kq, DI, (H, hd), dtype=dtype),
        "wk": dense_init(kk, DI, (H, hd), dtype=dtype),
        "wv": dense_init(kv, DI, (H, hd), dtype=dtype),
        "w_i": dense_init(ki, DI, H, dtype=jnp.float32),
        "w_f": dense_init(kf, DI, H, dtype=jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),   # open forget gates at init
        "b_i": jnp.zeros((H,), jnp.float32),
        "w_down": dense_init(kd, DI, D, dtype=dtype),
    }


def _mlstm_qkvg(params, x):
    up = jnp.einsum("bsd,de->bse", x, params["w_up"])
    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", x, params["w_gate"]))
    q = jnp.einsum("bse,ehk->bshk", up, params["wq"])
    k = jnp.einsum("bse,ehk->bshk", up, params["wk"])
    v = jnp.einsum("bse,ehk->bshk", up, params["wv"])
    i_pre = jnp.einsum("bse,eh->bsh", up.astype(jnp.float32), params["w_i"]) + params["b_i"]
    f_pre = jnp.einsum("bse,eh->bsh", up.astype(jnp.float32), params["w_f"]) + params["b_f"]
    return up, gate, q, k, v, i_pre, f_pre


def mlstm_fwd(params, spec: XLSTMSpec, x: jnp.ndarray):
    """Parallel (quadratic) form, optionally row-blocked.

    The naive form materializes [b, s, s, h] decay/score tensors (53 GiB/dev
    at 1M tokens — EXPERIMENTS.md §Perf); with ``spec.t_block`` rows are
    processed in blocks of tb so peak temp is [b, tb, s, h].
    """
    b, s, _ = x.shape
    H, hd = spec.num_heads, spec.head_dim
    up, gate, q, k, v, i_pre, f_pre = _mlstm_qkvg(params, x)
    logf = jax.nn.log_sigmoid(f_pre)                       # [b,s,h]
    F = jnp.cumsum(logf, axis=1)                           # inclusive
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    j_pos = jnp.arange(s)

    def rows(q_t, F_t, pos_t):
        """q_t [b,tb,h,hd], F_t [b,tb,h], pos_t [tb] -> h rows [b,tb,h,hd]."""
        Dm = F_t[:, :, None, :] - F[:, None, :, :] + i_pre[:, None, :, :]
        causal = pos_t[:, None] >= j_pos[None, :]
        Dm = jnp.where(causal[None, :, :, None], Dm, _NEG)
        m = jnp.max(Dm, axis=2)
        W = jnp.exp(Dm - m[:, :, None, :])
        qk = jnp.einsum("bthk,bjhk->btjh", q_t.astype(jnp.float32), kf)
        S = (qk / math.sqrt(hd)) * W
        denom = jnp.maximum(jnp.abs(S.sum(axis=2)), jnp.exp(-m))
        return jnp.einsum("btjh,bjhk->bthk", S, vf) / denom[..., None]

    tb = spec.t_block
    if tb and s > tb and s % tb == 0:
        nb = s // tb
        qb = jnp.moveaxis(q.reshape(b, nb, tb, H, hd), 1, 0)
        Fb = jnp.moveaxis(F.reshape(b, nb, tb, H), 1, 0)
        pb = j_pos.reshape(nb, tb)

        def body(_, xs):
            return None, rows(*xs)

        _, hb = jax.lax.scan(body, None, (qb, Fb, pb))
        h = jnp.moveaxis(hb, 0, 1).reshape(b, s, H, hd)
    else:
        h = rows(q, F, j_pos)
    h = h.reshape(b, s, H * hd).astype(x.dtype) * gate
    out = jnp.einsum("bse,ed->bsd", h, params["w_down"])

    # final recurrent state for decode handoff
    FL = F[:, -1, :]                                       # [b,h]
    scale_j = FL[:, None, :] - F + i_pre                   # [b,s,h]
    m_state = jnp.maximum(jnp.max(scale_j, axis=1), 0.0)   # [b,h]
    w_j = jnp.exp(scale_j - m_state[:, None, :])
    C = jnp.einsum("bjh,bjhk,bjhl->bhkl", w_j, k.astype(jnp.float32), v.astype(jnp.float32))
    n = jnp.einsum("bjh,bjhk->bhk", w_j, k.astype(jnp.float32))
    return out, {"C": C, "n": n, "m": m_state}


def init_mlstm_state(spec: XLSTMSpec, batch: int):
    H, hd = spec.num_heads, spec.head_dim
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


def mlstm_decode(params, spec: XLSTMSpec, x: jnp.ndarray, state):
    """Recurrent form, one token. x [b,1,d]."""
    b = x.shape[0]
    H, hd = spec.num_heads, spec.head_dim
    up, gate, q, k, v, i_pre, f_pre = _mlstm_qkvg(params, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]                    # [b,h,hd]
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]                # [b,h]
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + state["m"], i_pre)
    f_s = jnp.exp(logf + state["m"] - m_new)[..., None]
    i_s = jnp.exp(i_pre - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = f_s[..., None] * state["C"] + i_s[..., None] * (kf[..., :, None] * vf[..., None, :])
    n = f_s * state["n"] + i_s * kf
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    num = jnp.einsum("bhk,bhkl->bhl", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, 1, H * hd).astype(x.dtype) * gate
    out = jnp.einsum("bse,ed->bsd", h, params["w_down"])
    return out, {"C": C, "n": n, "m": m_new}


# --------------------------- sLSTM ------------------------------------------

def init_slstm(key, spec: XLSTMSpec, dtype=jnp.float32):
    ku, kz, ki, kf, ko, kd = jax.random.split(key, 6)
    D, DI = spec.d_model, spec.d_inner
    return {
        "w_up": dense_init(ku, D, DI, dtype=dtype),
        "w_z": dense_init(kz, DI, DI, dtype=dtype),
        "w_i": dense_init(ki, DI, DI, dtype=jnp.float32),
        "w_f": dense_init(kf, DI, DI, dtype=jnp.float32),
        "w_o": dense_init(ko, DI, DI, dtype=dtype),
        "b_f": jnp.full((DI,), 3.0, jnp.float32),
        "w_down": dense_init(kd, DI, D, dtype=dtype),
    }


def _slstm_pre(params, x):
    up = jnp.einsum("bsd,de->bse", x, params["w_up"])
    z = jnp.tanh(jnp.einsum("bse,ef->bsf", up, params["w_z"]))
    i_pre = jnp.einsum("bse,ef->bsf", up.astype(jnp.float32), params["w_i"])
    f_pre = jnp.einsum("bse,ef->bsf", up.astype(jnp.float32), params["w_f"]) + params["b_f"]
    o = jax.nn.sigmoid(jnp.einsum("bse,ef->bsf", up, params["w_o"]))
    return z, i_pre, f_pre, o


def slstm_fwd(params, spec: XLSTMSpec, x: jnp.ndarray):
    """Associative (gate-input-only) sLSTM. x [b,s,d]."""
    z, i_pre, f_pre, o = _slstm_pre(params, x)
    logf = jax.nn.log_sigmoid(f_pre)
    i = jnp.exp(jnp.minimum(i_pre, 10.0))
    f = jnp.exp(logf)

    def combine(c1, c2):
        (f1, c1v, n1), (f2, c2v, n2) = c1, c2
        return f1 * f2, f2 * c1v + c2v, f2 * n1 + n2

    zf = z.astype(jnp.float32)
    _, c, n = jax.lax.associative_scan(
        combine, (f, i * zf, i), axis=1
    )
    h = o.astype(jnp.float32) * c / jnp.maximum(jnp.abs(n), 1.0)
    out = jnp.einsum("bse,ed->bsd", h.astype(x.dtype), params["w_down"])
    state = {"c": c[:, -1], "n": n[:, -1]}
    return out, state


def init_slstm_state(spec: XLSTMSpec, batch: int):
    DI = spec.d_inner
    return {"c": jnp.zeros((batch, DI), jnp.float32), "n": jnp.zeros((batch, DI), jnp.float32)}


def slstm_decode(params, spec: XLSTMSpec, x: jnp.ndarray, state):
    z, i_pre, f_pre, o = _slstm_pre(params, x)
    f = jnp.exp(jax.nn.log_sigmoid(f_pre[:, 0]))
    i = jnp.exp(jnp.minimum(i_pre[:, 0], 10.0))
    c = f * state["c"] + i * z[:, 0].astype(jnp.float32)
    n = f * state["n"] + i
    h = o[:, 0].astype(jnp.float32) * c / jnp.maximum(jnp.abs(n), 1.0)
    out = jnp.einsum("be,ed->bd", h.astype(x.dtype), params["w_down"])[:, None, :]
    return out, {"c": c, "n": n}
