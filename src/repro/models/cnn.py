"""CNNs for the faithful AdaptCL reproduction: VGG16 + ResNet bottleneck nets.

These carry real BatchNorm scaling factors — the importance signal of
CIG-BNscalor — and a filter-level prunable unit space.  Parameters are flat
``{path: array}`` dicts so `core.aggregation` / `core.masks` can slice and
embed sub-models directly (shapes are read from the arrays, so a reconfigured
smaller model runs through the same ``cnn_apply``).

Pruning protocol (paper Appendix B): VGG16 — all conv layers prunable, the
final FC is not; ResNet — the stem conv and the last conv of each residual
block (and shortcuts) are not pruned, interior convs are.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masks import UnitLayer, UnitSpace

__all__ = [
    "CNNConfig",
    "cnn_flops",
    "cnn_flops_from_shapes",
    "vgg_config",
    "resnet_config",
    "VGG16_CIFAR",
    "VGG11_SMALL",
    "RESNET50_TINY",
    "RESNET20_SMALL",
    "init_cnn",
    "cnn_apply",
    "build_unit_space",
    "extract_bn_scales",
]


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    kind: str                      # "vgg" | "resnet"
    num_classes: int
    image_size: int
    # vgg: plan entries are ints (conv width) or "M" (maxpool)
    plan: Tuple = ()
    # resnet: stem width + (block_count, width) per stage
    stem: int = 64
    stages: Tuple[Tuple[int, int], ...] = ()
    bottleneck: bool = True


def vgg_config(name, plan, num_classes=10, image_size=32) -> CNNConfig:
    return CNNConfig(name=name, kind="vgg", plan=tuple(plan), num_classes=num_classes, image_size=image_size)


def resnet_config(name, stem, stages, num_classes=200, image_size=64, bottleneck=True) -> CNNConfig:
    return CNNConfig(
        name=name, kind="resnet", stem=stem, stages=tuple(stages),
        num_classes=num_classes, image_size=image_size, bottleneck=bottleneck,
    )


VGG16_CIFAR = vgg_config(
    "vgg16_cifar",
    [64, 64, "M", 128, 128, "M", 256, 256, 256, "M", 512, 512, 512, "M", 512, 512, 512, "M"],
)
# reduced same-family net for fast CPU FL simulation
VGG11_SMALL = vgg_config("vgg11_small", [16, "M", 32, "M", 64, 64, "M", 64, 64, "M"])
RESNET50_TINY = resnet_config("resnet50_tiny", 64, [(3, 64), (4, 128), (6, 256), (3, 512)])
RESNET20_SMALL = resnet_config(
    "resnet20_small", 16, [(2, 16), (2, 32), (2, 64)], num_classes=10, image_size=32, bottleneck=False
)


# ---------------------------------------------------------------------------
# init / apply
# ---------------------------------------------------------------------------

def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout), jnp.float32)
    return w * np.sqrt(2.0 / fan_in)


def _conv_names(cfg: CNNConfig) -> List[Tuple[str, int, int, bool]]:
    """[(name, ksize, stride, prunable)] in order, for vgg plans."""
    out = []
    i = 0
    for entry in cfg.plan:
        if entry == "M":
            continue
        out.append((f"conv{i}", 3, 1, True))
        i += 1
    return out


def init_cnn(key, cfg: CNNConfig) -> Dict[str, jnp.ndarray]:
    params: Dict[str, jnp.ndarray] = {}
    keys = iter(jax.random.split(key, 256))

    def add_conv(name, kh, cin, cout):
        params[f"{name}/w"] = _conv_init(next(keys), kh, kh, cin, cout)
        params[f"{name}/bn_g"] = jnp.ones((cout,))
        params[f"{name}/bn_b"] = jnp.zeros((cout,))
        return cout

    if cfg.kind == "vgg":
        cin = 3
        i = 0
        for entry in cfg.plan:
            if entry == "M":
                continue
            cin = add_conv(f"conv{i}", 3, cin, int(entry))
            i += 1
        params["fc/w"] = (
            jax.random.truncated_normal(next(keys), -2, 2, (cin, cfg.num_classes), jnp.float32)
            * np.sqrt(1.0 / cin)
        )
        params["fc/b"] = jnp.zeros((cfg.num_classes,))
    else:  # resnet
        cin = add_conv("stem", 3, 3, cfg.stem)
        for si, (nblocks, width) in enumerate(cfg.stages):
            for bi in range(nblocks):
                pre = f"s{si}b{bi}"
                out_w = width * (4 if cfg.bottleneck else 1)
                if cfg.bottleneck:
                    add_conv(f"{pre}/c1", 1, cin, width)
                    add_conv(f"{pre}/c2", 3, width, width)
                    add_conv(f"{pre}/c3", 1, width, out_w)
                else:
                    add_conv(f"{pre}/c1", 3, cin, width)
                    add_conv(f"{pre}/c2", 3, width, out_w)
                if cin != out_w:
                    add_conv(f"{pre}/sc", 1, cin, out_w)
                cin = out_w
        params["fc/w"] = (
            jax.random.truncated_normal(next(keys), -2, 2, (cin, cfg.num_classes), jnp.float32)
            * np.sqrt(1.0 / cin)
        )
        params["fc/b"] = jnp.zeros((cfg.num_classes,))
    return params


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _bn(x, g, b, eps=1e-5):
    mu = x.mean(axis=(0, 1, 2))
    var = x.var(axis=(0, 1, 2))
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _cbr(params, name, x, stride=1, relu=True):
    x = _conv(x, params[f"{name}/w"], stride)
    x = _bn(x, params[f"{name}/bn_g"], params[f"{name}/bn_b"])
    return jax.nn.relu(x) if relu else x


def cnn_apply(
    params: Dict[str, jnp.ndarray], cfg: CNNConfig, x: jnp.ndarray,
    stats: dict | None = None,
) -> jnp.ndarray:
    """x: [b, h, w, 3] -> logits [b, classes]. Shapes come from the params.

    If ``stats`` (a dict) is passed, per-conv mean|activation| per filter is
    recorded into it — the data-dependent signal for the HRank-style
    importance baseline (Fig. 2 reproduction).
    """

    def rec(name, h):
        if stats is not None:
            stats[name] = jnp.abs(h).mean(axis=(0, 1, 2))
        return h

    if cfg.kind == "vgg":
        i = 0
        for entry in cfg.plan:
            if entry == "M":
                x = jax.lax.reduce_window(
                    x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
                )
            else:
                x = rec(f"conv{i}", _cbr(params, f"conv{i}", x))
                i += 1
        x = x.mean(axis=(1, 2))
    else:
        x = _cbr(params, "stem", x)
        for si, (nblocks, width) in enumerate(cfg.stages):
            for bi in range(nblocks):
                pre = f"s{si}b{bi}"
                stride = 2 if (bi == 0 and si > 0) else 1
                h = rec(f"{pre}/c1", _cbr(params, f"{pre}/c1", x, stride))
                if cfg.bottleneck:
                    h = rec(f"{pre}/c2", _cbr(params, f"{pre}/c2", h))
                    h = _cbr(params, f"{pre}/c3", h, relu=False)
                else:
                    h = _cbr(params, f"{pre}/c2", h, relu=False)
                if f"{pre}/sc/w" in params:
                    x = _cbr(params, f"{pre}/sc", x, stride, relu=False)
                elif stride != 1:
                    x = x[:, ::stride, ::stride, :]
                x = jax.nn.relu(x + h)
        x = x.mean(axis=(1, 2))
    return x @ params["fc/w"] + params["fc/b"]


def cnn_flops(params: Dict, cfg: CNNConfig) -> float:
    """Per-image forward FLOPs of the (possibly reconfigured) model."""
    return cnn_flops_from_shapes({k: v.shape for k, v in params.items()}, cfg)


def cnn_flops_from_shapes(shapes: Dict[str, tuple], cfg: CNNConfig) -> float:
    """``cnn_flops`` from shape tuples alone (no arrays materialized) — the
    resident fleet engine's channel model derives sub-model FLOPs from the
    global index via ``core.aggregation.subparam_shapes``."""
    total = 0.0
    hw = cfg.image_size
    if cfg.kind == "vgg":
        i = 0
        for entry in cfg.plan:
            if entry == "M":
                hw //= 2
            else:
                total += 2.0 * hw * hw * int(np.prod(shapes[f"conv{i}/w"]))
                i += 1
    else:
        total += 2.0 * hw * hw * int(np.prod(shapes["stem/w"]))
        for si, (nblocks, _) in enumerate(cfg.stages):
            for bi in range(nblocks):
                if bi == 0 and si > 0:
                    hw //= 2
                pre = f"s{si}b{bi}"
                for c in ("c1", "c2", "c3", "sc"):
                    key = f"{pre}/{c}/w"
                    if key in shapes:
                        total += 2.0 * hw * hw * int(np.prod(shapes[key]))
    total += 2.0 * int(np.prod(shapes["fc/w"]))
    return total


# ---------------------------------------------------------------------------
# prunable unit metadata
# ---------------------------------------------------------------------------

def _prunable_convs(cfg: CNNConfig) -> List[Tuple[str, int, str]]:
    """[(conv_name, width, next_consumer)] — convs whose OUTPUT filters prune."""
    out = []
    if cfg.kind == "vgg":
        convs = [e for e in cfg.plan if e != "M"]
        for i, w in enumerate(convs):
            nxt = f"conv{i+1}" if i + 1 < len(convs) else "fc"
            out.append((f"conv{i}", int(w), nxt))
    else:
        # interior convs only (paper: keep stem, block-last conv, shortcuts)
        for si, (nblocks, width) in enumerate(cfg.stages):
            for bi in range(nblocks):
                pre = f"s{si}b{bi}"
                if cfg.bottleneck:
                    out.append((f"{pre}/c1", width, f"{pre}/c2"))
                    out.append((f"{pre}/c2", width, f"{pre}/c3"))
                else:
                    out.append((f"{pre}/c1", width, f"{pre}/c2"))
    return out


def build_unit_space(cfg: CNNConfig, params) -> Tuple[UnitSpace, Dict[str, list]]:
    """Returns (UnitSpace, unit_map path->[(unit_layer, axis)])."""
    unit_map: Dict[str, list] = {}
    layers = []
    prunable = _prunable_convs(cfg)
    prunable_names = {n for n, _, _ in prunable}
    for name, width, nxt in prunable:
        w = params[f"{name}/w"]
        kh, kw, cin, cout = w.shape
        # per-filter cost: own kernel column + bn(2) + consumer input slice
        cost = kh * kw * cin + 2
        if nxt == "fc":
            cost += params["fc/w"].shape[1]
        else:
            nw = params[f"{nxt}/w"]
            cost += nw.shape[0] * nw.shape[1] * nw.shape[3]
        layers.append(UnitLayer(name=name, num_units=cout, unit_param_cost=int(cost), min_units=2))
        unit_map.setdefault(f"{name}/w", []).append((name, 3))
        unit_map.setdefault(f"{name}/bn_g", []).append((name, 0))
        unit_map.setdefault(f"{name}/bn_b", []).append((name, 0))
        if nxt == "fc":
            unit_map.setdefault("fc/w", []).append((name, 0))
        else:
            unit_map.setdefault(f"{nxt}/w", []).append((name, 2))
    total = sum(int(np.prod(v.shape)) for v in params.values())
    prunable_mass = sum(l.num_units * l.unit_param_cost for l in layers)
    space = UnitSpace(layers=tuple(layers), fixed_params=total - prunable_mass)
    return space, unit_map


def extract_bn_scales(params, cfg: CNNConfig) -> Dict[str, np.ndarray]:
    """|BN gamma| per prunable filter — the CIG-BNscalor signal (§III-D)."""
    return {
        name: np.abs(np.asarray(params[f"{name}/bn_g"], np.float64))
        for name, _, _ in _prunable_convs(cfg)
    }
