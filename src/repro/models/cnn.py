"""CNNs for the faithful AdaptCL reproduction: VGG16 + ResNet bottleneck nets.

These carry real BatchNorm scaling factors — the importance signal of
CIG-BNscalor — and a filter-level prunable unit space.  Parameters are flat
``{path: array}`` dicts so `core.aggregation` / `core.masks` can slice and
embed sub-models directly (shapes are read from the arrays, so a reconfigured
smaller model runs through the same ``cnn_apply``).

Pruning protocol (paper Appendix B): VGG16 — all conv layers prunable, the
final FC is not; ResNet — the stem conv and the last conv of each residual
block (and shortcuts) are not pruned, interior convs are.

**Compute paths** (``cnn_apply(compute=...)``): ``"dense"`` runs the convs as
``lax.conv`` at whatever shapes the params carry (the masked engines pass
base-shape params with pruned coordinates zeroed — full device FLOPs).
``"block_skip"`` lowers every conv through an im2col/patches →
``[M, K] x [K, N]`` formulation onto the ``kernels.pruned_matmul`` block-skip
Pallas kernel, with per-layer 0/1 ``unit_masks`` wired along the pruning
topology (a conv's out-mask is its own unit mask; its in-mask is its
producer's, repeated over the kh*kw patch taps — the patches feature dim is
channel-major, so a pruned *prefix* of channels is a contiguous K prefix and
whole tail blocks skip).  The dense head rides the same kernel.  Device FLOPs
then track retention instead of base shape; ``cnn_block_compute`` is the
host-side proxy for exactly how many blocks/FLOPs that dispatch executes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.masks import UnitLayer, UnitSpace
from repro.kernels.ops import pruned_matmul

__all__ = [
    "CNNConfig",
    "cnn_flops",
    "cnn_flops_from_shapes",
    "cnn_block_compute",
    "conv_mask_wiring",
    "prunable_layer_names",
    "vgg_config",
    "resnet_config",
    "VGG16_CIFAR",
    "VGG11_SMALL",
    "RESNET50_TINY",
    "RESNET20_SMALL",
    "init_cnn",
    "cnn_apply",
    "build_unit_space",
    "extract_bn_scales",
]


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    kind: str                      # "vgg" | "resnet"
    num_classes: int
    image_size: int
    # vgg: plan entries are ints (conv width) or "M" (maxpool)
    plan: Tuple = ()
    # resnet: stem width + (block_count, width) per stage
    stem: int = 64
    stages: Tuple[Tuple[int, int], ...] = ()
    bottleneck: bool = True


def vgg_config(name, plan, num_classes=10, image_size=32) -> CNNConfig:
    return CNNConfig(name=name, kind="vgg", plan=tuple(plan), num_classes=num_classes, image_size=image_size)


def resnet_config(name, stem, stages, num_classes=200, image_size=64, bottleneck=True) -> CNNConfig:
    return CNNConfig(
        name=name, kind="resnet", stem=stem, stages=tuple(stages),
        num_classes=num_classes, image_size=image_size, bottleneck=bottleneck,
    )


VGG16_CIFAR = vgg_config(
    "vgg16_cifar",
    [64, 64, "M", 128, 128, "M", 256, 256, 256, "M", 512, 512, 512, "M", 512, 512, 512, "M"],
)
# reduced same-family net for fast CPU FL simulation
VGG11_SMALL = vgg_config("vgg11_small", [16, "M", 32, "M", 64, 64, "M", 64, 64, "M"])
RESNET50_TINY = resnet_config("resnet50_tiny", 64, [(3, 64), (4, 128), (6, 256), (3, 512)])
RESNET20_SMALL = resnet_config(
    "resnet20_small", 16, [(2, 16), (2, 32), (2, 64)], num_classes=10, image_size=32, bottleneck=False
)


# ---------------------------------------------------------------------------
# init / apply
# ---------------------------------------------------------------------------

def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.truncated_normal(key, -2, 2, (kh, kw, cin, cout), jnp.float32)
    return w * np.sqrt(2.0 / fan_in)


def _conv_names(cfg: CNNConfig) -> List[Tuple[str, int, int, bool]]:
    """[(name, ksize, stride, prunable)] in order, for vgg plans."""
    out = []
    i = 0
    for entry in cfg.plan:
        if entry == "M":
            continue
        out.append((f"conv{i}", 3, 1, True))
        i += 1
    return out


def init_cnn(key, cfg: CNNConfig) -> Dict[str, jnp.ndarray]:
    params: Dict[str, jnp.ndarray] = {}
    keys = iter(jax.random.split(key, 256))

    def add_conv(name, kh, cin, cout):
        params[f"{name}/w"] = _conv_init(next(keys), kh, kh, cin, cout)
        params[f"{name}/bn_g"] = jnp.ones((cout,))
        params[f"{name}/bn_b"] = jnp.zeros((cout,))
        return cout

    if cfg.kind == "vgg":
        cin = 3
        i = 0
        for entry in cfg.plan:
            if entry == "M":
                continue
            cin = add_conv(f"conv{i}", 3, cin, int(entry))
            i += 1
        params["fc/w"] = (
            jax.random.truncated_normal(next(keys), -2, 2, (cin, cfg.num_classes), jnp.float32)
            * np.sqrt(1.0 / cin)
        )
        params["fc/b"] = jnp.zeros((cfg.num_classes,))
    else:  # resnet
        cin = add_conv("stem", 3, 3, cfg.stem)
        for si, (nblocks, width) in enumerate(cfg.stages):
            for bi in range(nblocks):
                pre = f"s{si}b{bi}"
                out_w = width * (4 if cfg.bottleneck else 1)
                if cfg.bottleneck:
                    add_conv(f"{pre}/c1", 1, cin, width)
                    add_conv(f"{pre}/c2", 3, width, width)
                    add_conv(f"{pre}/c3", 1, width, out_w)
                else:
                    add_conv(f"{pre}/c1", 3, cin, width)
                    add_conv(f"{pre}/c2", 3, width, out_w)
                if cin != out_w:
                    add_conv(f"{pre}/sc", 1, cin, out_w)
                cin = out_w
        params["fc/w"] = (
            jax.random.truncated_normal(next(keys), -2, 2, (cin, cfg.num_classes), jnp.float32)
            * np.sqrt(1.0 / cin)
        )
        params["fc/b"] = jnp.zeros((cfg.num_classes,))
    return params


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _conv_block_skip(x, w, in_vec, out_vec, stride, blocks, interpret):
    """Conv as im2col patches → block-skip masked matmul.

    ``conv_general_dilated_patches`` emits the K dim channel-major
    (cin * kh * kw, spatial taps minor), so the per-channel ``in_vec`` repeats
    over kh*kw taps and a pruned channel *prefix* stays a contiguous K prefix
    — the layout that makes whole-block skipping effective under CIG/prefix
    retention."""
    kh, kw, cin, cout = w.shape
    p = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    b, hh, ww, _ = p.shape
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    in_mask = (
        jnp.ones((cin * kh * kw,), jnp.float32) if in_vec is None
        else jnp.repeat(in_vec.astype(jnp.float32), kh * kw)
    )
    out_mask = (
        jnp.ones((cout,), jnp.float32) if out_vec is None
        else out_vec.astype(jnp.float32)
    )
    y = pruned_matmul(
        p.reshape(b * hh * ww, cin * kh * kw), wmat, in_mask, out_mask,
        block_m=blocks[0], block_n=blocks[1], block_k=blocks[2],
        interpret=interpret,
    )
    return y.reshape(b, hh, ww, cout)


def _bn(x, g, b, eps=1e-5):
    mu = x.mean(axis=(0, 1, 2))
    var = x.var(axis=(0, 1, 2))
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def conv_mask_wiring(cfg: CNNConfig) -> Dict[str, Tuple[Optional[str], Optional[str]]]:
    """conv/head name -> (input unit layer, output unit layer), ``None`` for
    an unpruned side.  This is the pruning topology ``_prunable_convs``
    encodes, viewed from each consumer: a conv's out-mask is its own unit
    layer, its in-mask is its producer's."""
    wiring: Dict[str, Tuple[Optional[str], Optional[str]]] = {}
    if cfg.kind == "vgg":
        convs = [e for e in cfg.plan if e != "M"]
        for i in range(len(convs)):
            wiring[f"conv{i}"] = (f"conv{i-1}" if i > 0 else None, f"conv{i}")
        wiring["fc"] = (f"conv{len(convs)-1}" if convs else None, None)
    else:
        wiring["stem"] = (None, None)
        for si, (nblocks, _) in enumerate(cfg.stages):
            for bi in range(nblocks):
                pre = f"s{si}b{bi}"
                if cfg.bottleneck:
                    wiring[f"{pre}/c1"] = (None, f"{pre}/c1")
                    wiring[f"{pre}/c2"] = (f"{pre}/c1", f"{pre}/c2")
                    wiring[f"{pre}/c3"] = (f"{pre}/c2", None)
                else:
                    wiring[f"{pre}/c1"] = (None, f"{pre}/c1")
                    wiring[f"{pre}/c2"] = (f"{pre}/c1", None)
                wiring[f"{pre}/sc"] = (None, None)
        wiring["fc"] = (None, None)
    return wiring


def prunable_layer_names(cfg: CNNConfig) -> Tuple[str, ...]:
    """Unit-layer names of the prunable convs, in network order."""
    return tuple(name for name, _, _ in _prunable_convs(cfg))


def cnn_apply(
    params: Dict[str, jnp.ndarray], cfg: CNNConfig, x: jnp.ndarray,
    stats: dict | None = None,
    compute: str = "dense",
    unit_masks: Optional[Dict[str, jnp.ndarray]] = None,
    blocks: Tuple[int, int, int] = (128, 128, 128),
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """x: [b, h, w, 3] -> logits [b, classes]. Shapes come from the params.

    If ``stats`` (a dict) is passed, per-conv mean|activation| per filter is
    recorded into it — the data-dependent signal for the HRank-style
    importance baseline (Fig. 2 reproduction).

    ``compute="block_skip"`` dispatches every conv (and the fc head) through
    the ``kernels.pruned_matmul`` block-skip kernel with ``unit_masks``
    ({prunable layer name: [width] 0/1}) wired along ``conv_mask_wiring`` —
    numerically the same function as the dense path on masked params (pruned
    units are exact zeros either way), but fully-pruned mask blocks execute
    zero MXU passes.  ``blocks``/``interpret`` forward to the kernel
    (``interpret=None`` auto-selects: interpreter everywhere but TPU).
    """
    if compute not in ("dense", "block_skip"):
        raise ValueError(f"unknown compute path {compute!r}")
    bs = compute == "block_skip"
    if bs and interpret is None:
        from repro.kernels.ops import auto_interpret

        interpret = auto_interpret()
    wiring = conv_mask_wiring(cfg) if bs else {}
    um = unit_masks or {}

    def mask_vec(lname):
        return None if lname is None else um.get(lname)

    def cbr(name, h, stride=1, relu=True):
        if bs:
            in_l, out_l = wiring[name]
            h = _conv_block_skip(
                h, params[f"{name}/w"], mask_vec(in_l), mask_vec(out_l),
                stride, blocks, interpret,
            )
        else:
            h = _conv(h, params[f"{name}/w"], stride)
        h = _bn(h, params[f"{name}/bn_g"], params[f"{name}/bn_b"])
        return jax.nn.relu(h) if relu else h

    def rec(name, h):
        if stats is not None:
            stats[name] = jnp.abs(h).mean(axis=(0, 1, 2))
        return h

    if cfg.kind == "vgg":
        i = 0
        for entry in cfg.plan:
            if entry == "M":
                x = jax.lax.reduce_window(
                    x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
                )
            else:
                x = rec(f"conv{i}", cbr(f"conv{i}", x))
                i += 1
        x = x.mean(axis=(1, 2))
    else:
        x = cbr("stem", x)
        for si, (nblocks, width) in enumerate(cfg.stages):
            for bi in range(nblocks):
                pre = f"s{si}b{bi}"
                stride = 2 if (bi == 0 and si > 0) else 1
                h = rec(f"{pre}/c1", cbr(f"{pre}/c1", x, stride))
                if cfg.bottleneck:
                    h = rec(f"{pre}/c2", cbr(f"{pre}/c2", h))
                    h = cbr(f"{pre}/c3", h, relu=False)
                else:
                    h = cbr(f"{pre}/c2", h, relu=False)
                if f"{pre}/sc/w" in params:
                    x = cbr(f"{pre}/sc", x, stride, relu=False)
                elif stride != 1:
                    x = x[:, ::stride, ::stride, :]
                x = jax.nn.relu(x + h)
        x = x.mean(axis=(1, 2))
    if bs:
        in_l, _ = wiring["fc"]
        fc_in = mask_vec(in_l)
        head = pruned_matmul(
            x, params["fc/w"],
            jnp.ones((x.shape[1],), jnp.float32) if fc_in is None
            else fc_in.astype(jnp.float32),
            jnp.ones((params["fc/w"].shape[1],), jnp.float32),
            block_m=blocks[0], block_n=blocks[1], block_k=blocks[2],
            interpret=interpret,
        )
        return head + params["fc/b"]
    return x @ params["fc/w"] + params["fc/b"]


def cnn_flops(params: Dict, cfg: CNNConfig) -> float:
    """Per-image forward FLOPs of the (possibly reconfigured) model."""
    return cnn_flops_from_shapes({k: v.shape for k, v in params.items()}, cfg)


def cnn_flops_from_shapes(shapes: Dict[str, tuple], cfg: CNNConfig) -> float:
    """``cnn_flops`` from shape tuples alone (no arrays materialized) — the
    resident fleet engine's channel model derives sub-model FLOPs from the
    global index via ``core.aggregation.subparam_shapes``."""
    total = 0.0
    hw = cfg.image_size
    if cfg.kind == "vgg":
        i = 0
        for entry in cfg.plan:
            if entry == "M":
                hw //= 2
            else:
                total += 2.0 * hw * hw * int(np.prod(shapes[f"conv{i}/w"]))
                i += 1
    else:
        total += 2.0 * hw * hw * int(np.prod(shapes["stem/w"]))
        for si, (nblocks, _) in enumerate(cfg.stages):
            for bi in range(nblocks):
                if bi == 0 and si > 0:
                    hw //= 2
                pre = f"s{si}b{bi}"
                for c in ("c1", "c2", "c3", "sc"):
                    key = f"{pre}/{c}/w"
                    if key in shapes:
                        total += 2.0 * hw * hw * int(np.prod(shapes[key]))
    total += 2.0 * int(np.prod(shapes["fc/w"]))
    return total


def _base_conv_geoms(cfg: CNNConfig) -> List[Tuple[str, int, int, int, int]]:
    """[(name, ksize, cin, cout, hw)] for every conv at BASE shapes, plus the
    final ("fc", 1, cin, classes, 1) head row — the per-image matmul geometry
    the block-skip dispatch runs at."""
    out: List[Tuple[str, int, int, int, int]] = []
    hw = cfg.image_size
    if cfg.kind == "vgg":
        cin, i = 3, 0
        for entry in cfg.plan:
            if entry == "M":
                hw //= 2
            else:
                out.append((f"conv{i}", 3, cin, int(entry), hw))
                cin, i = int(entry), i + 1
    else:
        out.append(("stem", 3, 3, cfg.stem, hw))
        cin = cfg.stem
        for si, (nblocks, width) in enumerate(cfg.stages):
            for bi in range(nblocks):
                if bi == 0 and si > 0:
                    hw //= 2
                pre = f"s{si}b{bi}"
                out_w = width * (4 if cfg.bottleneck else 1)
                if cfg.bottleneck:
                    out.append((f"{pre}/c1", 1, cin, width, hw))
                    out.append((f"{pre}/c2", 3, width, width, hw))
                    out.append((f"{pre}/c3", 1, width, out_w, hw))
                else:
                    out.append((f"{pre}/c1", 3, cin, width, hw))
                    out.append((f"{pre}/c2", 3, width, out_w, hw))
                if cin != out_w:
                    out.append((f"{pre}/sc", 1, cin, out_w, hw))
                cin = out_w
    out.append(("fc", 1, cin, cfg.num_classes, 1))
    return out


def cnn_block_compute(
    cfg: CNNConfig,
    unit_masks: Dict[str, np.ndarray],
    blocks: Tuple[int, int, int] = (128, 128, 128),
) -> Dict[str, float]:
    """Host-side proxy for what the ``block_skip`` dispatch executes per
    image: ``{"flops": ..., "blocks": ..., "blocks_total": ...}``.

    ``flops`` is forward multiply-adds over the *kept* K/N blocks of every
    conv-as-matmul (and the head), ``blocks`` the executed grid-cell count
    the kernel's prefetch flags produce, ``blocks_total`` the cell count a
    never-skipping dispatch would run — their ratio is the retention-tracking
    claim the benches assert without ever touching the device."""
    from repro.kernels.pruned_matmul import matmul_executed_blocks, matmul_executed_flops

    bm, bn, bk = blocks
    wiring = conv_mask_wiring(cfg)
    flops = 0.0
    cells = 0
    cells_total = 0
    for name, ks, cin, cout, hw in _base_conv_geoms(cfg):
        in_l, out_l = wiring[name]
        in_vec = unit_masks.get(in_l) if in_l is not None else None
        out_vec = unit_masks.get(out_l) if out_l is not None else None
        in_mask = (
            np.ones(cin * ks * ks, np.float32) if in_vec is None
            else np.repeat(np.asarray(in_vec, np.float32), ks * ks)
        )
        out_mask = np.ones(cout, np.float32) if out_vec is None else np.asarray(out_vec, np.float32)
        M = hw * hw
        flops += matmul_executed_flops(M, in_mask, out_mask, block_m=bm, block_n=bn, block_k=bk)
        cells += matmul_executed_blocks(M, in_mask, out_mask, block_m=bm, block_n=bn, block_k=bk)
        cells_total += matmul_executed_blocks(
            M, np.ones_like(in_mask), np.ones_like(out_mask),
            block_m=bm, block_n=bn, block_k=bk,
        )
    return {"flops": flops, "blocks": float(cells), "blocks_total": float(cells_total)}


# ---------------------------------------------------------------------------
# prunable unit metadata
# ---------------------------------------------------------------------------

def _prunable_convs(cfg: CNNConfig) -> List[Tuple[str, int, str]]:
    """[(conv_name, width, next_consumer)] — convs whose OUTPUT filters prune."""
    out = []
    if cfg.kind == "vgg":
        convs = [e for e in cfg.plan if e != "M"]
        for i, w in enumerate(convs):
            nxt = f"conv{i+1}" if i + 1 < len(convs) else "fc"
            out.append((f"conv{i}", int(w), nxt))
    else:
        # interior convs only (paper: keep stem, block-last conv, shortcuts)
        for si, (nblocks, width) in enumerate(cfg.stages):
            for bi in range(nblocks):
                pre = f"s{si}b{bi}"
                if cfg.bottleneck:
                    out.append((f"{pre}/c1", width, f"{pre}/c2"))
                    out.append((f"{pre}/c2", width, f"{pre}/c3"))
                else:
                    out.append((f"{pre}/c1", width, f"{pre}/c2"))
    return out


def build_unit_space(cfg: CNNConfig, params) -> Tuple[UnitSpace, Dict[str, list]]:
    """Returns (UnitSpace, unit_map path->[(unit_layer, axis)])."""
    unit_map: Dict[str, list] = {}
    layers = []
    prunable = _prunable_convs(cfg)
    prunable_names = {n for n, _, _ in prunable}
    for name, width, nxt in prunable:
        w = params[f"{name}/w"]
        kh, kw, cin, cout = w.shape
        # per-filter cost: own kernel column + bn(2) + consumer input slice
        cost = kh * kw * cin + 2
        if nxt == "fc":
            cost += params["fc/w"].shape[1]
        else:
            nw = params[f"{nxt}/w"]
            cost += nw.shape[0] * nw.shape[1] * nw.shape[3]
        layers.append(UnitLayer(name=name, num_units=cout, unit_param_cost=int(cost), min_units=2))
        unit_map.setdefault(f"{name}/w", []).append((name, 3))
        unit_map.setdefault(f"{name}/bn_g", []).append((name, 0))
        unit_map.setdefault(f"{name}/bn_b", []).append((name, 0))
        if nxt == "fc":
            unit_map.setdefault("fc/w", []).append((name, 0))
        else:
            unit_map.setdefault(f"{nxt}/w", []).append((name, 2))
    total = sum(int(np.prod(v.shape)) for v in params.values())
    prunable_mass = sum(l.num_units * l.unit_param_cost for l in layers)
    space = UnitSpace(layers=tuple(layers), fixed_params=total - prunable_mass)
    return space, unit_map


def extract_bn_scales(params, cfg: CNNConfig) -> Dict[str, np.ndarray]:
    """|BN gamma| per prunable filter — the CIG-BNscalor signal (§III-D)."""
    return {
        name: np.abs(np.asarray(params[f"{name}/bn_g"], np.float64))
        for name, _, _ in _prunable_convs(cfg)
    }
