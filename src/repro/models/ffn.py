"""Feed-forward layers: gated (SwiGLU/GeGLU) and plain MLP.

The hidden dimension d_ff is a prunable unit axis for AdaptCL: every hidden
unit owns one column of w_gate/w_up and one row of w_down — a "group" in the
group-lasso sense (Eq. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import dense_init, gelu, silu
from repro.sharding.specs import constrain

__all__ = ["FFNSpec", "init_ffn", "ffn_fwd"]


@dataclasses.dataclass(frozen=True)
class FFNSpec:
    d_model: int
    d_ff: int
    gated: bool = True          # SwiGLU (llama-family) vs plain 2-layer MLP
    activation: str = "silu"    # "silu" | "gelu"


def init_ffn(key, spec: FFNSpec, dtype=jnp.float32):
    kg, ku, kd = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ku, spec.d_model, spec.d_ff, dtype=dtype),
        "w_down": dense_init(kd, spec.d_ff, spec.d_model, dtype=dtype),
    }
    if spec.gated:
        p["w_gate"] = dense_init(kg, spec.d_model, spec.d_ff, dtype=dtype)
    return p


def ffn_fwd(params, spec: FFNSpec, x: jnp.ndarray) -> jnp.ndarray:
    act = silu if spec.activation == "silu" else gelu
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    if spec.gated:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
        h = act(gate) * up
    else:
        h = act(up)
    h = constrain(h, [(0, "batch"), (2, "model")])
    return constrain(jnp.einsum("bsf,fd->bsd", h, params["w_down"]), [(0, "batch")])
