"""Sparse training: group-lasso regularization (AdaptCL Eq. 1, after [22]).

The loss is  CE + lambda * sum_g sqrt(|g|) * ||theta_g||_2  where each group g
is the parameter slice owned by one prunable unit (a conv filter's kernel
column + BN gamma/beta + consumer input slice; an FFN column; ...).  Shrinking
whole groups toward zero is what makes later structural pruning cheap in
accuracy — the "-S" (sparse) variants of every baseline use this same term.

Groups are derived from the same ``unit_map`` used for pruning/aggregation,
so the regularizer automatically follows the reconfigured sub-model.
"""
from __future__ import annotations

from typing import Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["group_lasso_penalty", "unit_group_norms"]


def _axes_except(arr, axis):
    return tuple(i for i in range(arr.ndim) if i != axis)


def unit_group_norms(
    params: Mapping[str, jnp.ndarray], unit_map: Mapping[str, Sequence[Tuple[str, int]]]
) -> Dict[str, jnp.ndarray]:
    """Per-unit L2 norm (and the group sizes) aggregated across all arrays a
    unit touches.  Returns {unit_layer: [num_units] norms}."""
    sq: Dict[str, jnp.ndarray] = {}
    size: Dict[str, int] = {}
    for path, entries in unit_map.items():
        arr = params.get(path)
        if arr is None:
            continue
        for lname, axis in entries:
            s = jnp.sum(jnp.square(arr.astype(jnp.float32)), axis=_axes_except(arr, axis))
            sq[lname] = sq.get(lname, 0.0) + s
            size[lname] = size.get(lname, 0) + int(arr.size // arr.shape[axis])
    return {k: jnp.sqrt(jnp.maximum(v, 1e-12)) for k, v in sq.items()}, size  # type: ignore[return-value]


def group_lasso_penalty(
    params: Mapping[str, jnp.ndarray],
    unit_map: Mapping[str, Sequence[Tuple[str, int]]],
    lam: float,
) -> jnp.ndarray:
    """lambda * sum_g sqrt(|g|) ||theta_g||_2 over prunable units."""
    norms, sizes = unit_group_norms(params, unit_map)
    total = jnp.zeros((), jnp.float32)
    for lname, n in norms.items():
        total = total + jnp.sqrt(jnp.asarray(float(sizes[lname]))) * jnp.sum(n)
    return lam * total
