"""Sparse training: group-lasso regularization (AdaptCL Eq. 1, after [22]).

The loss is  CE + lambda * sum_g sqrt(|g|) * ||theta_g||_2  where each group g
is the parameter slice owned by one prunable unit (a conv filter's kernel
column + BN gamma/beta + consumer input slice; an FFN column; ...).  Shrinking
whole groups toward zero is what makes later structural pruning cheap in
accuracy — the "-S" (sparse) variants of every baseline use this same term.

Groups are derived from the same ``unit_map`` used for pruning/aggregation,
so the regularizer automatically follows the reconfigured sub-model.
"""
from __future__ import annotations

from typing import Dict, Mapping, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "group_lasso_penalty",
    "unit_group_norms",
    "group_size_sqrt",
    "group_size_sqrt_from_shapes",
]


def _axes_except(arr, axis):
    return tuple(i for i in range(arr.ndim) if i != axis)


def unit_group_norms(
    params: Mapping[str, jnp.ndarray], unit_map: Mapping[str, Sequence[Tuple[str, int]]]
) -> Dict[str, jnp.ndarray]:
    """Per-unit L2 norm (and the group sizes) aggregated across all arrays a
    unit touches.  Returns {unit_layer: [num_units] norms}."""
    sq: Dict[str, jnp.ndarray] = {}
    size: Dict[str, int] = {}
    for path, entries in unit_map.items():
        arr = params.get(path)
        if arr is None:
            continue
        for lname, axis in entries:
            s = jnp.sum(jnp.square(arr.astype(jnp.float32)), axis=_axes_except(arr, axis))
            sq[lname] = sq.get(lname, 0.0) + s
            size[lname] = size.get(lname, 0) + int(arr.size // arr.shape[axis])
    return {k: jnp.sqrt(jnp.maximum(v, 1e-12)) for k, v in sq.items()}, size  # type: ignore[return-value]


def group_size_sqrt_from_shapes(
    shapes: Mapping[str, Sequence[int]], unit_map
) -> Dict[str, float]:
    """sqrt(|g|) per unit layer from shape tuples alone.

    The resident fleet engine never materializes reconfigured arrays, so it
    derives the group-lasso size factors from ``subparam_shapes`` output."""
    size: Dict[str, int] = {}
    for path, entries in unit_map.items():
        shape = shapes.get(path)
        if shape is None:
            continue
        n = int(np.prod(shape))
        for lname, axis in entries:
            size[lname] = size.get(lname, 0) + n // int(shape[axis])
    return {k: float(np.sqrt(v)) for k, v in size.items()}


def group_size_sqrt(params, unit_map) -> Dict[str, float]:
    """sqrt(|g|) per unit layer, from the (possibly reconfigured) shapes.

    Masked-mode training keeps every worker at base shape, where the group
    sizes read off the arrays would be the *base* model's; computing them
    from the worker's reconfigured sub-params and feeding them to
    ``group_lasso_penalty`` keeps the penalty identical to the physically
    reconfigured model's."""
    return group_size_sqrt_from_shapes(
        {path: arr.shape for path, arr in params.items()}, unit_map
    )


def group_lasso_penalty(
    params: Mapping[str, jnp.ndarray],
    unit_map: Mapping[str, Sequence[Tuple[str, int]]],
    lam: float,
    size_sqrt: Mapping[str, jnp.ndarray] | None = None,
) -> jnp.ndarray:
    """lambda * sum_g sqrt(|g|) ||theta_g||_2 over prunable units.

    ``size_sqrt`` overrides the shape-derived sqrt(|g|) factor per unit layer
    (see ``group_size_sqrt``); groups whose norm is exactly zero contribute a
    constant and zero gradient, so masked sub-models are penalized like their
    reconfigured twins."""
    norms, sizes = unit_group_norms(params, unit_map)
    total = jnp.zeros((), jnp.float32)
    for lname, n in norms.items():
        if size_sqrt is not None:
            factor = size_sqrt[lname]
        else:
            factor = jnp.sqrt(jnp.asarray(float(sizes[lname])))
        total = total + factor * jnp.sum(n)
    return lam * total
