"""Optimizers (pure JAX, pytree-generic): SGD, momentum, AdamW.

Minimal optax-style API: ``init(params) -> state``, ``update(grads, state,
params) -> (updates, state)``; updates are *added* to params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "momentum", "adamw", "apply_updates"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def sgd(lr: float) -> Optimizer:
    return Optimizer(
        init=lambda p: (),
        update=lambda g, s, p: (jax.tree.map(lambda x: -lr * x, g), s),
    )


def momentum(lr: float, beta: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        new_v = jax.tree.map(lambda v, g: beta * v + g, state, grads)
        return jax.tree.map(lambda v: -lr * v, new_v), new_v

    return Optimizer(init, update)


def adamw(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    state_dtype=jnp.float32,
) -> Optimizer:
    """AdamW. ``state_dtype=jnp.bfloat16`` halves optimizer memory (a
    beyond-paper §Perf lever for the 400B config)."""

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: (b1 * m_.astype(jnp.float32) + (1 - b1) * g).astype(state_dtype), state["m"], grads)
        v = jax.tree.map(lambda v_, g: (b2 * v_.astype(jnp.float32) + (1 - b2) * jnp.square(g)).astype(state_dtype), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m_, v_, p):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            step = mh / (jnp.sqrt(vh) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(jnp.float32)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)
