"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]: MoE.

24 layers, d_model=1024, 16H (GQA kv=8, head_dim 64), 32 experts top-8 with
per-expert d_ff=512, vocab=49155.  `window_size` is populated only when the
long-context sliding-window variant is selected (launch --variant windowed).
"""
from repro.models.config import ModelConfig
from .base import register

CFG = register(ModelConfig(
    name="granite-moe-1b-a400m",
    arch_type="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    block_pattern=("moe",),
    num_experts=32,
    experts_per_tok=8,
    tie_embeddings=True,
))
