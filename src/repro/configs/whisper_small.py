"""Whisper-small [arXiv:2212.04356]: encoder-decoder, audio frontend stubbed.

12 encoder + 12 decoder layers, d_model=768, 12H (MHA kv=12, head_dim 64),
d_ff=3072, vocab=51865, LayerNorm + learned positions + GELU, non-gated MLP.
The mel+conv frontend is a stub: input_specs provides frame embeddings.
"""
from repro.models.config import ModelConfig
from .base import register

CFG = register(ModelConfig(
    name="whisper-small",
    arch_type="audio",
    num_layers=12,
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    norm_style="layernorm",
    pos_embed="learned",
    max_position=32_768,
    activation="gelu",
    gated_ffn=False,
    frontend="audio",
    tie_embeddings=True,
))
