"""Qwen3-32B [hf:Qwen/Qwen3-8B family]: dense, qk-norm, GQA.

64 layers, d_model=5120, 64H (GQA kv=8, head_dim 128), d_ff=25600,
vocab=151936, RMSNorm qk-norm on every attention head.
"""
from repro.models.config import ModelConfig
from .base import register

CFG = register(ModelConfig(
    name="qwen3-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25_600,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
))
