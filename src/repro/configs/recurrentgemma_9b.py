"""RecurrentGemma-9B [arXiv:2402.19427]: RG-LRU + local attention, 1:2 ratio.

38 layers (12 full (rglru, rglru, local) periods + 2 remainder rglru),
d_model=4096, 16 heads (MQA kv=1, head_dim 256), d_ff=12288, vocab=256000,
local window 2048, GeGLU, Gemma-style embedding scale.
"""
from repro.models.config import ModelConfig
from .base import register

CFG = register(ModelConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local"),
    window_size=2048,
    rnn_width=4096,
    rnn_heads=16,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
))
