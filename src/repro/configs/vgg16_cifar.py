"""VGG16 on CIFAR-class data — the paper's own primary model (§IV-A, [21]'s
variation). Used by the faithful FL reproduction; prunable units are conv
filters, importance = true BN scaling factors (CIG-BNscalor)."""
from repro.models.cnn import VGG16_CIFAR as CFG  # noqa: F401
from repro.models.cnn import VGG11_SMALL as SMOKE_CFG  # reduced same-family
