"""Llama-4-Maverick-400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E family].

48 layers with interleaved dense/MoE FFN (24 (attn, moe) periods, matching
Maverick's every-other-layer MoE), d_model=5120, 40H (GQA kv=8, head_dim 128),
MoE 128 experts top-1 with per-expert d_ff=8192 plus a shared expert,
vocab=202048 — ~400B total, ~17B active.
"""
from repro.models.config import ModelConfig
from .base import register

CFG = register(ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    block_pattern=("attn", "moe"),
    num_experts=128,
    experts_per_tok=1,
    shared_expert=True,
    rope_theta=500_000.0,
    tie_embeddings=False,
))
