"""ResNet50 on Tiny-ImageNet-class data — the paper's second model (§IV-A).
Pruning protocol per Appendix B: stem conv, block-last convs and shortcuts
are never pruned."""
from repro.models.cnn import RESNET50_TINY as CFG  # noqa: F401
from repro.models.cnn import RESNET20_SMALL as SMOKE_CFG
