"""InternLM2-1.8B [arXiv:2403.17297]: dense GQA.

24 layers, d_model=2048, 16H (GQA kv=8, head_dim 128), d_ff=8192, vocab=92544.
"""
from repro.models.config import ModelConfig
from .base import register

CFG = register(ModelConfig(
    name="internlm2-1.8b",
    arch_type="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92_544,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
))
