"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B family]: dense MHA with QKV bias.

64 layers, d_model=5120, 40H (kv=40, head_dim 128), d_ff=27392, vocab=152064.
"""
from repro.models.config import ModelConfig
from .base import register

CFG = register(ModelConfig(
    name="qwen1.5-32b",
    arch_type="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=27_392,
    vocab_size=152_064,
    qkv_bias=True,
    tie_embeddings=False,
))
