"""Config registry + input-shape definitions + smoke-reduction helper."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from repro.models.config import ModelConfig

__all__ = ["register", "get_config", "smoke_config", "list_archs", "SHAPES", "InputShape"]

_REGISTRY: Dict[str, ModelConfig] = {}


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def list_archs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    cfg = get_config(name)
    pattern = cfg.block_pattern
    if len(pattern) > 2:
        # keep family coverage: one recurrent + one attention-ish kind
        kinds = list(dict.fromkeys(pattern))  # unique, order-preserving
        pattern = tuple(kinds[:2]) if len(kinds) >= 2 else (pattern[0],) * 2
    d_model = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    heads = (heads // kv) * kv or kv
    kw = dict(
        num_layers=2,
        block_pattern=pattern,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        window_size=min(cfg.window_size, 32) if cfg.window_size else None,
        max_position=4096,
        num_experts=min(cfg.num_experts, 4) if cfg.num_experts else 0,
        experts_per_tok=min(cfg.experts_per_tok, 2) if cfg.experts_per_tok else 0,
        # drop-free capacity: incremental decode == teacher-forced forward
        moe_capacity_factor=float(max(cfg.num_experts, 1)),
        rnn_width=256 if cfg.rnn_width else None,
        rnn_heads=4 if cfg.rnn_width else cfg.rnn_heads,
        encoder_layers=2 if cfg.encoder_layers else 0,
        num_prefix_embeds=8 if cfg.num_prefix_embeds else 0,
        dtype="float32",
        attn_q_block=None,
        scan_layers=cfg.scan_layers,
    )
    return cfg.replace(**kw)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        gemma2_2b,
        granite_moe_1b_a400m,
        internlm2_1_8b,
        internvl2_76b,
        llama4_maverick_400b_a17b,
        qwen1_5_32b,
        qwen3_32b,
        recurrentgemma_9b,
        whisper_small,
        xlstm_1_3b,
    )
