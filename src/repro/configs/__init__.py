from .base import SHAPES, InputShape, get_config, list_archs, smoke_config  # noqa: F401
