"""Gemma-2-2B [arXiv:2408.00118]: alternating local/global attention, softcaps.

26 layers (13 (local, global) periods), d_model=2304, 8H (GQA kv=4,
head_dim 256), d_ff=9216, vocab=256000, window 4096, attn softcap 50,
final-logit softcap 30, GeGLU, embedding scale.
"""
from repro.models.config import ModelConfig
from .base import register

CFG = register(ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    block_pattern=("local", "attn"),
    window_size=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
))
