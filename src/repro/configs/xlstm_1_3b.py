"""xLSTM-1.3B [arXiv:2405.04517]: sLSTM + mLSTM blocks (7:1 ratio).

48 blocks (6 (7x mLSTM + 1x sLSTM) periods), d_model=2048, 4 heads,
projection factor 1.0 (d_ff=0 — width lives in the cell projections;
factor chosen to match the 1.3B parameter budget),
vocab=50304.
"""
from repro.models.config import ModelConfig
from .base import register

CFG = register(ModelConfig(
    name="xlstm-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    xlstm_proj_factor=1.0,
    tie_embeddings=True,
))
