"""InternVL2-76B [arXiv:2404.16821]: InternViT + LLaMA3-70B-class LM backbone.

LM backbone only (the ViT frontend is a stub per the assignment carve-out):
80 layers, d_model=8192, 64H (GQA kv=8, head_dim 128), d_ff=28672,
vocab=128256.  `num_prefix_embeds` precomputed patch embeddings are fused
early into the sequence (input_specs provides them).
"""
from repro.models.config import ModelConfig
from .base import register

CFG = register(ModelConfig(
    name="internvl2-76b",
    arch_type="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    frontend="vision",
    num_prefix_embeds=256,
    rope_theta=500_000.0,
    tie_embeddings=False,
))
