"""Pruned (block-sparse) matmul Pallas kernel — AdaptCL's masked-training hot spot.

TPU adaptation of the paper's sub-model compute (DESIGN.md §2): instead of a
GPU gather-matmul, unit pruning is expressed as 0/1 masks over the K (input
units) and N (output units) dims — plus an optional row mask over M — and the
kernel is a 128-aligned blocked matmul that (a) applies the masks fused in
VMEM (no separate ``W * mask`` materialization in HBM) and (b) *skips whole
blocks* whose units are all pruned, via scalar-prefetched block-keep flags —
the MXU-granular analogue of NetworkReconfigure.  Skipping is three-way:

* ``k_keep`` — a K (contraction) block with no surviving input unit
  contributes nothing to the accumulator, so its MXU pass is skipped;
* ``n_keep`` — an N (output-column) block whose units are all pruned can only
  produce zeros, so its accumulation is skipped and the finish pass writes the
  zeros via the fused ``out_mask`` multiply;
* ``m_keep`` — same for fully-masked row blocks (``row_mask``), which is what
  lets the backward pass skip pruned *output-unit rows* of dW.

With CIG pruning the retained set is a fixed prefix of the frozen importance
order, so after the one-time relabeling of units into that order (the
``index`` importance method is exactly this relabeled view) the retained set
is a coordinate prefix: whole tail blocks die at once, block occupancy of the
surviving prefix stays high, and executed FLOPs scale ~ with the retention
ratio instead of rounding up per scattered unit.

Shapes need not be multiples of the block sizes: inputs are zero-padded up to
block multiples (padded mask entries are 0, so padded blocks are *skipped*,
not computed) and the output is sliced back to ``[M, N]``.

Grid: (M/bm, N/bn, K/bk), K innermost (sequential); fp32 VMEM accumulator.

``pruned_matmul`` is the differentiable entry point: a ``jax.custom_vjp``
whose backward pass reuses this same kernel —

    dX = ((dY * out_mask) @ Wᵀ) * in_mask * row_mask   (skips pruned N blocks
                                                        in the contraction and
                                                        pruned K output blocks)
    dW = ((Xᵀ * row_mask) @ dY) * in_mask[:,None] * out_mask[None,:]
                                                       (skips pruned K row
                                                        blocks and N column
                                                        blocks)

so masked gradients are exactly zero on pruned units (the fleet invariant:
``core.fleet.FleetState`` param rows stay exactly 0 on pruned coordinates)
and backward FLOPs track retention the same way forward FLOPs do.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "pruned_matmul_kernel_call",
    "pruned_matmul",
    "block_keep_count",
    "matmul_executed_blocks",
    "matmul_executed_flops",
]


def _kernel(
    m_keep_ref, k_keep_ref, n_keep_ref,
    x_ref, w_ref, in_mask_ref, out_mask_ref, row_mask_ref,
    o_ref, acc_ref,
):
    mi = pl.program_id(0)
    ni = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(
        (m_keep_ref[mi] > 0) & (n_keep_ref[ni] > 0) & (k_keep_ref[ki] > 0)
    )
    def _compute():
        xm = x_ref[...].astype(jnp.float32) * in_mask_ref[...].astype(jnp.float32)[None, :]
        acc_ref[...] += jax.lax.dot_general(
            xm,
            w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...]
            * out_mask_ref[...].astype(jnp.float32)[None, :]
            * row_mask_ref[...].astype(jnp.float32)[:, None]
        ).astype(o_ref.dtype)


def _pad_to(a: jnp.ndarray, mults) -> jnp.ndarray:
    pads = [(0, -int(s) % int(m)) for s, m in zip(a.shape, mults)]
    if any(p for _, p in pads):
        a = jnp.pad(a, pads)
    return a


def _keep_flags(mask: jnp.ndarray, block: int) -> jnp.ndarray:
    """1 per block if any unit in the block survives (scalar prefetch).
    ``mask`` must already be padded to a multiple of ``block``."""
    nb = mask.shape[0] // block
    return (mask.reshape(nb, block).sum(axis=1) > 0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def _call(
    x: jnp.ndarray,          # [M, K]
    w: jnp.ndarray,          # [K, N]
    in_mask: jnp.ndarray,    # [K] 0/1
    out_mask: jnp.ndarray,   # [N] 0/1
    row_mask: jnp.ndarray,   # [M] 0/1
    block_m: int,
    block_n: int,
    block_k: int,
    interpret: bool,
) -> jnp.ndarray:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and in_mask.shape == (K,) and out_mask.shape == (N,)
    assert row_mask.shape == (M,)
    # ragged shapes: zero-pad up to block multiples; padded mask entries are
    # 0, so padded blocks are skipped entirely, and the output is sliced back
    x = _pad_to(x, (block_m, block_k))
    w = _pad_to(w, (block_k, block_n))
    in_mask = _pad_to(in_mask, (block_k,))
    out_mask = _pad_to(out_mask, (block_n,))
    row_mask = _pad_to(row_mask, (block_m,))
    Mp, Kp = x.shape
    Np = w.shape[1]

    m_keep = _keep_flags(row_mask, block_m)
    k_keep = _keep_flags(in_mask, block_k)
    n_keep = _keep_flags(out_mask, block_n)

    grid = (Mp // block_m, Np // block_n, Kp // block_k)
    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda i, j, k, *_: (i, k)),
                pl.BlockSpec((block_k, block_n), lambda i, j, k, *_: (k, j)),
                pl.BlockSpec((block_k,), lambda i, j, k, *_: (k,)),
                pl.BlockSpec((block_n,), lambda i, j, k, *_: (j,)),
                pl.BlockSpec((block_m,), lambda i, j, k, *_: (i,)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k, *_: (i, j)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        interpret=interpret,
    )(m_keep, k_keep, n_keep, x, w, in_mask, out_mask, row_mask)
    return out[:M, :N]


def pruned_matmul_kernel_call(
    x: jnp.ndarray,          # [M, K]
    w: jnp.ndarray,          # [K, N]
    in_mask: jnp.ndarray,    # [K] 0/1
    out_mask: jnp.ndarray,   # [N] 0/1
    row_mask: jnp.ndarray | None = None,   # [M] 0/1 (default: all rows live)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Forward-only kernel call (no autodiff rule); see ``pruned_matmul``."""
    if row_mask is None:
        row_mask = jnp.ones((x.shape[0],), jnp.float32)
    return _call(x, w, in_mask, out_mask, row_mask, block_m, block_n, block_k, interpret)


# ---------------------------------------------------------------------------
# custom VJP: the backward pass is the same block-skip kernel, re-oriented
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _pm_ad(x, w, in_mask, out_mask, row_mask, block_m, block_n, block_k, interpret):
    return _call(x, w, in_mask, out_mask, row_mask, block_m, block_n, block_k, interpret)


def _pm_fwd(x, w, in_mask, out_mask, row_mask, block_m, block_n, block_k, interpret):
    y = _call(x, w, in_mask, out_mask, row_mask, block_m, block_n, block_k, interpret)
    return y, (x, w, in_mask, out_mask, row_mask)


def _pm_bwd(block_m, block_n, block_k, interpret, res, g):
    x, w, in_mask, out_mask, row_mask = res
    g = g.astype(x.dtype)
    # dX [M, K] = ((g * out_mask) @ Wᵀ) * in_mask[None, :] * row_mask[:, None]
    # contraction over N skips pruned N blocks; pruned K output blocks skip too
    dx = _call(
        g, w.T, out_mask, in_mask, row_mask,
        block_m, block_k, block_n, interpret,
    )
    # dW [K, N] = ((Xᵀ * row_mask) @ g) * in_mask[:, None] * out_mask[None, :]
    # pruned K row blocks and pruned N column blocks are both skipped
    dw = _call(
        x.T, g, row_mask, out_mask, in_mask,
        block_k, block_n, block_m, interpret,
    )
    return (
        dx, dw,
        jnp.zeros_like(in_mask), jnp.zeros_like(out_mask), jnp.zeros_like(row_mask),
    )


_pm_ad.defvjp(_pm_fwd, _pm_bwd)


def pruned_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    in_mask: jnp.ndarray,
    out_mask: jnp.ndarray,
    row_mask: jnp.ndarray | None = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Differentiable block-skip masked matmul:
    ``y = ((x * in_mask) @ w) * out_mask[None, :] * row_mask[:, None]``.

    Gradients flow to ``x`` and ``w`` only (masks are treated as constant 0/1
    structure) and are *exactly* zero on pruned units.  Any M/K/N is accepted
    (padded to block multiples internally); vmap-able over a leading batch
    axis with per-row masks — the resident fleet's one-program dispatch.
    """
    if row_mask is None:
        row_mask = jnp.ones((x.shape[0],), jnp.float32)
    return _pm_ad(x, w, in_mask, out_mask, row_mask, block_m, block_n, block_k, interpret)


# ---------------------------------------------------------------------------
# host-side block accounting (the interpret-mode FLOPs proxy)
# ---------------------------------------------------------------------------

def block_keep_count(mask: np.ndarray, block: int) -> int:
    """Number of blocks with >= 1 surviving unit, after padding to a multiple
    of ``block`` (the same flags the kernel prefetches)."""
    mask = np.asarray(mask)
    pad = -len(mask) % block
    if pad:
        mask = np.concatenate([mask, np.zeros(pad, mask.dtype)])
    return int((mask.reshape(-1, block).sum(axis=1) > 0).sum())


def matmul_executed_blocks(
    M: int,
    in_mask: np.ndarray,
    out_mask: np.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
) -> int:
    """Grid cells whose MXU pass actually executes (rows assumed all live)."""
    m_blocks = -(-M // block_m)
    return m_blocks * block_keep_count(in_mask, block_k) * block_keep_count(out_mask, block_n)


def matmul_executed_flops(
    M: int,
    in_mask: np.ndarray,
    out_mask: np.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
) -> float:
    """Forward multiply-add FLOPs the kernel executes: 2 * M * K_exec * N_exec
    where K_exec/N_exec count *blocks kept*, not units kept — the honest
    device cost of block-granular skipping (M is not padded: the row dim is
    batch-dependent and never pruned in the forward pass)."""
    k_exec = block_keep_count(in_mask, block_k) * block_k
    n_exec = block_keep_count(out_mask, block_n) * block_n
    return 2.0 * M * k_exec * n_exec
