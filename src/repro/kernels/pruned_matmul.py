"""Pruned (block-sparse) matmul Pallas kernel — AdaptCL's masked-training hot spot.

TPU adaptation of the paper's sub-model compute (DESIGN.md §2): instead of a
GPU gather-matmul, unit pruning is expressed as 0/1 masks over the K (input
units) and N (output units) dims, and the kernel is a 128-aligned blocked
matmul that (a) applies the masks fused in VMEM (no separate ``W * mask``
materialization in HBM) and (b) *skips whole K-blocks* whose units are all
pruned, via scalar-prefetched block-keep flags — the MXU-granular analogue of
NetworkReconfigure.  With CIG pruning the retained set is a fixed prefix of
the frozen importance order, so block occupancy stays high and skipping is
effective (FLOPs scale ~ with the retention ratio).

Grid: (M/bm, N/bn, K/bk), K innermost (sequential); fp32 VMEM accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pruned_matmul_kernel_call"]


def _kernel(k_keep_ref, x_ref, w_ref, in_mask_ref, out_mask_ref, o_ref, acc_ref):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k_keep_ref[ki] > 0)
    def _compute():
        xm = x_ref[...].astype(jnp.float32) * in_mask_ref[...].astype(jnp.float32)[None, :]
        acc_ref[...] += jax.lax.dot_general(
            xm,
            w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = (
            acc_ref[...] * out_mask_ref[...].astype(jnp.float32)[None, :]
        ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def pruned_matmul_kernel_call(
    x: jnp.ndarray,          # [M, K]
    w: jnp.ndarray,          # [K, N]
    in_mask: jnp.ndarray,    # [K] 0/1
    out_mask: jnp.ndarray,   # [N] 0/1
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and in_mask.shape == (K,) and out_mask.shape == (N,)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0, (
        f"dims ({M},{K},{N}) must be multiples of blocks ({block_m},{block_k},{block_n})"
    )
    nk = K // block_k
    # block-keep flags: 1 if any unit in the K block survives (scalar prefetch)
    k_keep = (in_mask.reshape(nk, block_k).sum(axis=1) > 0).astype(jnp.int32)

    grid = (M // block_m, N // block_n, nk)
    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_m, block_k), lambda i, j, k, keep: (i, k)),
                pl.BlockSpec((block_k, block_n), lambda i, j, k, keep: (k, j)),
                pl.BlockSpec((block_k,), lambda i, j, k, keep: (k,)),
                pl.BlockSpec((block_n,), lambda i, j, k, keep: (j,)),
            ],
            out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k, keep: (i, j)),
            scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(k_keep, x, w, in_mask, out_mask)
