"""RG-LRU linear-recurrence Pallas kernel (RecurrentGemma's temporal mixer).

Recurrence: ``h_t = a_t * h_{t-1} + x_t`` with per-channel decay a_t in (0,1).

TPU adaptation (DESIGN.md §2): the original GPU implementation is a custom
linear-scan kernel over warps; here the sequence is processed in VMEM-resident
blocks with the grid's seq dimension sequential.  Within a block the
recurrence is closed-form via log-space cumulative sums on the VPU:

    A_t   = prod_{i<=t} a_i  = exp(cumsum(log a))
    h_t   = A_t * (h_in + cumsum(x_t / A_t))

(valid because a > 0; the 1/A_t factor bounds block length — with a >= 0.9
and block 256, 1/A <= ? 0.9^-256 ~ 5e11, still inside f32 range; the Griffin
initialization keeps a in (0.9, 0.999)).  The carry ``h`` lives in VMEM
scratch and flows across seq blocks; batch/channel tiles are parallel.

Grid: (B/bb, R/bc, S/bs) with seq innermost-sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rg_lru_scan_kernel_call"]


def _kernel(a_ref, x_ref, h0_ref, o_ref, h_ref):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)        # [bb, bs, bc]
    x = x_ref[...].astype(jnp.float32)
    log_a = jnp.log(jnp.maximum(a, 1e-30))
    logA = jnp.cumsum(log_a, axis=1)          # within-block cumulative decay
    A = jnp.exp(logA)
    u = x * jnp.exp(-logA)
    h = A * (h_ref[...][:, None, :] + jnp.cumsum(u, axis=1))
    o_ref[...] = h.astype(o_ref.dtype)
    h_ref[...] = h[:, -1, :]


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_s", "block_c", "interpret")
)
def rg_lru_scan_kernel_call(
    x: jnp.ndarray,          # [b, s, r] gated inputs
    a: jnp.ndarray,          # [b, s, r] decays in (0, 1)
    h0: jnp.ndarray,         # [b, r] initial state
    *,
    block_b: int = 8,
    block_s: int = 256,
    block_c: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, s, r = x.shape
    assert a.shape == (b, s, r) and h0.shape == (b, r)
    assert b % block_b == 0 and s % block_s == 0 and r % block_c == 0

    grid = (b // block_b, r // block_c, s // block_s)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_s, block_c), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((block_b, block_s, block_c), lambda i, j, k: (i, k, j)),
            pl.BlockSpec((block_b, block_c), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_s, block_c), lambda i, j, k: (i, k, j)),
        scratch_shapes=[pltpu.VMEM((block_b, block_c), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((b, s, r), x.dtype),
        interpret=interpret,
    )(a, x, h0)
