"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["pruned_matmul_ref", "flash_attention_ref", "rg_lru_ref"]


def pruned_matmul_ref(
    x: jnp.ndarray,          # [m, k_full]
    w: jnp.ndarray,          # [k_full, n_full]
    in_idx: jnp.ndarray,     # [k_sub] retained input-unit ids (sorted)
    out_idx: jnp.ndarray,    # [n_sub] retained output-unit ids (sorted)
) -> jnp.ndarray:
    """y = x[:, in_idx] @ w[in_idx][:, out_idx] — the masked-training matmul
    of an AdaptCL sub-model expressed against base-model weights."""
    return jnp.take(x, in_idx, axis=1) @ jnp.take(
        jnp.take(w, in_idx, axis=0), out_idx, axis=1
    )


def flash_attention_ref(
    q: jnp.ndarray,          # [b, s, h, d]
    k: jnp.ndarray,          # [b, s, h, d]  (kv already repeated to h)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jnp.ndarray:
    b, s, h, d = q.shape
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / math.sqrt(d)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    keep = jnp.ones((s, s), bool)
    if causal:
        keep &= kp <= qp
    if window is not None:
        keep &= kp > qp - window
    scores = jnp.where(keep, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rg_lru_ref(
    x: jnp.ndarray,          # [b, s, r] gated inputs (i_t * x_t pre-applied upstream)
    a: jnp.ndarray,          # [b, s, r] per-step decay in (0, 1)
    h0: Optional[jnp.ndarray] = None,   # [b, r]
) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + x_t  (the RG-LRU core linear recurrence)."""
    b, s, r = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, r), x.dtype)

    def step(h, xs):
        a_t, x_t = xs
        h = a_t * h + x_t
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (a.swapaxes(0, 1).astype(jnp.float32),
                          x.swapaxes(0, 1).astype(jnp.float32)))
    return hs.swapaxes(0, 1).astype(x.dtype)
