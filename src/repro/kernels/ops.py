"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode —
the kernel body runs as Python/jnp over the same BlockSpec tiling, which is
what the allclose tests validate.  On a real TPU backend they compile to
Mosaic.  ``auto_interpret()`` picks per-backend.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_kernel_call
from .pruned_matmul import pruned_matmul as pruned_matmul_ad
from .rg_lru_scan import rg_lru_scan_kernel_call

__all__ = ["auto_interpret", "pruned_matmul", "flash_attention", "rg_lru_scan"]


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pruned_matmul(x, w, in_mask, out_mask, row_mask=None, **kw):
    """AdaptCL masked-training matmul: y = (x * in_mask) @ w * out_mask with
    whole pruned M/K/N blocks skipped.  Masks are 0/1 vectors in base
    coordinates; differentiable (custom VJP reuses the block-skip kernel),
    and any shape is accepted (padded to block multiples internally)."""
    kw.setdefault("interpret", auto_interpret())
    return pruned_matmul_ad(x, w, in_mask, out_mask, row_mask, **kw)


def flash_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                    softcap: Optional[float] = None, **kw):
    """Blocked online-softmax attention; K/V pre-repeated to query heads."""
    kw.setdefault("interpret", auto_interpret())
    return flash_attention_kernel_call(
        q, k, v, causal=causal, window=window, softcap=softcap, **kw
    )


def rg_lru_scan(x, a, h0=None, **kw):
    """RG-LRU linear recurrence h_t = a_t h_{t-1} + x_t over seq blocks."""
    if h0 is None:
        h0 = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)
    kw.setdefault("interpret", auto_interpret())
    return rg_lru_scan_kernel_call(x, a, h0, **kw)
