"""Flash attention Pallas kernel: blocked online-softmax, causal / sliding
window / Gemma-2 logit softcap.

TPU design: grid (batch*heads, q_blocks, kv_blocks) with the kv dimension
innermost and sequential; running max / denominator / output accumulator live
in VMEM scratch.  Block-level masking: kv blocks entirely above the causal
diagonal, or entirely outside the sliding window, are skipped with
``pl.when`` (no MXU work issued) — at 32k with a 4k window this skips ~7/8 of
all blocks, which is exactly the prefill saving the windowed archs
(RecurrentGemma / Gemma-2) rely on.

K/V are expected pre-repeated to the query head count (GQA handled upstream,
matching the model's head-major layout).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale,
            causal, window, softcap, block_q, block_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    kv_start = ki * block_kv

    # block-level skip: entirely future (causal) or entirely out of window
    live = jnp.asarray(True)
    if causal:
        live &= kv_start <= q_start + block_q - 1
    if window is not None:
        # live iff newest kv of the block is inside the window of the oldest
        # query of the block: kv_end > q_start - window
        live &= kv_start + block_kv - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale              # [bq, d]
        k = k_ref[0].astype(jnp.float32)                      # [bkv, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq, bkv]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        keep = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            keep &= k_pos <= q_pos
        if window is not None:
            keep &= k_pos > q_pos - window
        s = jnp.where(keep, s, _NEG)

        m_prev = m_ref[...]                                   # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                # [bq, bkv]
        alpha = jnp.exp(m_prev - m_new)                       # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)                      # [bkv, d]
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_kv", "interpret"),
)
def flash_attention_kernel_call(
    q: jnp.ndarray,          # [b, s, h, d]
    k: jnp.ndarray,          # [b, s, h, d] (kv repeated to h)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, s, h, d = q.shape
    assert k.shape == v.shape == (b, s, h, d)
    assert s % block_q == 0 and s % block_kv == 0
    scale = 1.0 / math.sqrt(d)
    # fold (b, h) into the leading grid dim; layout [bh, s, d]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    grid = (b * h, s // block_q, s // block_kv)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_kv=block_kv,
    )
    of = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda g, i, j: (g, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return of.reshape(b, h, s, d).transpose(0, 2, 1, 3)
