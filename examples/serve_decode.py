"""Batched serving example: prefill + ring-buffer decode on a reduced config,
including a capability-adapted (AdaptCL-pruned) replica — the serving-side
analogue of the paper's heterogeneous workers.

    PYTHONPATH=src python examples/serve_decode.py [--arch recurrentgemma-9b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import list_archs, smoke_config
from repro.launch.serve import serve_batch
from repro.models import transformer as T
from repro.models.config import apply_retention, param_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-9b", choices=list_archs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    for gamma in (1.0, 0.5):
        cfg = smoke_config(args.arch)
        if gamma < 1.0:
            cfg = apply_retention(cfg, gamma)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        prompts = jax.random.randint(jax.random.PRNGKey(1), (args.batch, 16), 0, cfg.vocab_size)
        extra = {}
        if cfg.num_prefix_embeds:
            extra["prefix_embeds"] = jnp.zeros((args.batch, cfg.num_prefix_embeds, cfg.d_model))
        if cfg.encoder_layers:
            extra["enc_embeds"] = jnp.zeros((args.batch, 16, cfg.d_model))
        t0 = time.perf_counter()
        gen = serve_batch(cfg, params, prompts, args.new_tokens, extra)
        dt = time.perf_counter() - t0
        print(f"[serve] {args.arch} gamma={gamma}: {param_count(cfg):,} params, "
              f"{args.batch * args.new_tokens / dt:6.1f} tok/s, sample {np.asarray(gen[0])[:6]}")


if __name__ == "__main__":
    main()
