"""Quickstart: train a reduced assigned-architecture config on synthetic LM
data, then serve it — the 60-second tour of the public API.

    PYTHONPATH=src python examples/quickstart.py [--arch gemma2-2b]
"""
import argparse

import jax
import numpy as np

from repro.configs import list_archs, smoke_config
from repro.launch.serve import serve_batch
from repro.launch.train import train_loop
from repro.models import transformer as T
from repro.models.config import apply_retention, param_count


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    print(f"[quickstart] {cfg.name} (reduced): {param_count(cfg):,} params")
    params, losses, dt = train_loop(cfg, steps=args.steps, batch=8, lr=1e-3)
    print(f"[quickstart] trained {args.steps} steps in {dt:.1f}s: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss should decrease"

    # AdaptCL: reconfigure to a 60%-retention sub-model and serve it
    sub_cfg = apply_retention(cfg, 0.6)
    sub_params = T.init_params(jax.random.PRNGKey(1), sub_cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size)
    gen = serve_batch(sub_cfg, sub_params, prompts, new_tokens=8)
    print(f"[quickstart] gamma=0.6 sub-model ({param_count(sub_cfg):,} params) "
          f"served {gen.shape[1]} tokens/prompt: {np.asarray(gen[0])}")


if __name__ == "__main__":
    main()
