"""End-to-end driver: the faithful AdaptCL reproduction (paper Alg. 1+2).

Runs the full collaborative-learning simulation — 10 heterogeneous workers,
synchronous rounds, dynamic pruned-rate learning, CIG-BNscalor pruning,
By-worker aggregation — against the FedAVG-S baseline, and prints the
Table II-style comparison.

    PYTHONPATH=src python examples/adaptcl_sim.py [--rounds 30] [--sigma 2] \
        [--workers 10] [--engine masked] [--scenario 0.5,0.1,0.02]

``--engine masked`` runs the resident fleet engine (core.fleet.FleetState):
all workers live as [W, ...] base-shape stacks on device, so host wall-clock
is ~flat in worker count — try ``--workers 200 --engine masked``.

``--scenario C,dropout,churn`` turns on the flaky-fleet scenario layer
(per-round client sampling with fraction C, straggler dropout, slot churn).
Async methods accept sampling only (C,0,0): a static C*W cohort joins the
event loop and the resident engine sizes device compute to it.

``--compute block_skip`` (with ``--engine masked``) dispatches the convs +
head through the ``kernels/pruned_matmul`` block-skip Pallas kernel, so a
pruned worker's device FLOPs track its retention (``--compute-blocks``
sets the tile sizes; shrink them for CPU interpret runs).

``--mesh-devices N`` (with ``--engine fused``, sync methods) shards the
resident ``[W, ...]`` stacks over an N-device fleet mesh axis — the fused
scan runs per shard with two-tier psum aggregation; on CPU expose virtual
devices first: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--methods`` picks the frameworks to compare (first = baseline for the
speedup line), e.g. the async schedulers on the resident engine:

    PYTHONPATH=src python examples/adaptcl_sim.py --engine masked \
        --methods fedasync_s,ssp_s,dcasgd_s --async-window 50 --rounds 6
"""
import argparse

import numpy as np

from repro.core.scenario import ScenarioConfig
from repro.core.simulation import SimConfig, run_simulation
from repro.core.timing import HeterogeneityConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--sigma", type=float, default=2.0)
    ap.add_argument("--noniid", type=float, default=80.0)
    ap.add_argument("--workers", type=int, default=10)
    ap.add_argument("--engine", default="sequential",
                    choices=("sequential", "bucketed", "masked", "fused"))
    ap.add_argument("--round-fusion", type=int, default=0,
                    help="fused engine: max rounds per on-device lax.scan "
                         "chunk (0 = fuse up to the next prune-rate-learning "
                         "event)")
    ap.add_argument("--compute", default="dense",
                    choices=("dense", "block_skip"),
                    help="masked engine's device compute path: block_skip "
                         "dispatches convs + head through the "
                         "kernels/pruned_matmul block-skip Pallas kernel so "
                         "device FLOPs track retention (requires --engine "
                         "masked; interpret-mode off-TPU)")
    ap.add_argument("--compute-blocks", default="128,128,128",
                    metavar="BM,BN,BK",
                    help="pruned_matmul tile sizes; shrink (e.g. 128,8,8) "
                         "for fine-grained CPU/interpret runs")
    ap.add_argument("--mesh-devices", type=int, default=0, metavar="N",
                    help="mesh-sharded fleet: shard the [W, ...] stacks over "
                         "N devices (fused sync engine only; W %% N == 0). "
                         "On a CPU-only host expose virtual devices first: "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8")
    ap.add_argument("--scenario", default=None, metavar="C,DROPOUT,CHURN",
                    help="client sampling fraction, dropout prob, churn prob")
    ap.add_argument("--methods", default="fedavg_s,adaptcl",
                    help="comma list of frameworks to compare (first = "
                         "baseline): fedavg, fedavg_s, adaptcl, fedasync_s, "
                         "ssp_s, dcasgd_s")
    ap.add_argument("--async-window", type=float, default=0.0,
                    help="virtual window batching async commits into one "
                         "fleet call (async methods only)")
    args = ap.parse_args()

    scenario = None
    if args.scenario:
        c, drop, churn = (float(v) for v in args.scenario.split(","))
        scenario = ScenarioConfig(participation=c, dropout=drop, churn=churn)

    mesh = None
    if args.mesh_devices:
        from repro.launch.mesh import make_fleet_mesh

        mesh = make_fleet_mesh(args.mesh_devices)

    methods = [m.strip() for m in args.methods.split(",") if m.strip()]
    results = {}
    for method in methods:
        sim = SimConfig(
            method=method,
            rounds=args.rounds,
            prune_interval=5,
            num_workers=args.workers,
            noniid_s=args.noniid,
            het=HeterogeneityConfig(num_workers=args.workers, sigma=args.sigma),
            engine=args.engine,
            round_fusion=args.round_fusion,
            compute=args.compute,
            compute_blocks=tuple(int(v) for v in args.compute_blocks.split(",")),
            scenario=scenario,
            async_window=args.async_window,
            mesh=mesh,
        )
        r = run_simulation(sim)
        results[method] = r
        print(f"[{method:9s}] best_acc={r.best_acc:.3f} time={r.total_time:.0f}s "
              f"param_red={r.param_reduction:.1%} "
              f"(host: {r.walltime_s:.1f}s, {r.recompiles} compiles, "
              f"{r.host_roundtrips} roundtrips, engine={r.engine})")
        if mesh is not None:
            print(f"            mesh: {r.n_devices} devices x "
                  f"W_local={args.workers // r.fleet_axis_size} "
                  f"spec={r.shard_spec}")
        if args.compute == "block_skip":
            print(f"            compute=block_skip: "
                  f"flops_exec/ideal={r.flops_executed / max(r.flops_ideal, 1e-9):.3f} "
                  f"blocks/img(final)={r.blocks_per_image_final:.0f}")
        if method == "adaptcl":
            print(f"            retentions={[round(g, 2) for g in r.retentions]}")
            hs = [f"{h:.2f}" for _, h in r.het_traj[:: max(1, args.rounds // 8)]]
            print(f"            heterogeneity trajectory: {' -> '.join(hs)}")

    if len(methods) > 1:
        base, last = results[methods[0]], results[methods[-1]]
        note = "  (paper at sigma=2: 1.78x)" if methods == ["fedavg_s", "adaptcl"] else ""
        print(f"\n{methods[-1]} vs {methods[0]} speedup: "
              f"{base.total_time / last.total_time:.2f}x{note}   "
              f"dAcc={last.best_acc - base.best_acc:+.3f}")


if __name__ == "__main__":
    main()
