"""End-to-end driver: the faithful AdaptCL reproduction (paper Alg. 1+2).

Runs the full collaborative-learning simulation — 10 heterogeneous workers,
synchronous rounds, dynamic pruned-rate learning, CIG-BNscalor pruning,
By-worker aggregation — against the FedAVG-S baseline, and prints the
Table II-style comparison.

    PYTHONPATH=src python examples/adaptcl_sim.py [--rounds 30] [--sigma 2] \
        [--engine masked]

``--engine masked`` (or ``bucketed``) batches all workers' local training
into vmapped device programs (core.fleet) — same results, much faster host
wall-clock at high worker counts.
"""
import argparse

import numpy as np

from repro.core.simulation import SimConfig, run_simulation
from repro.core.timing import HeterogeneityConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--sigma", type=float, default=2.0)
    ap.add_argument("--noniid", type=float, default=80.0)
    ap.add_argument("--engine", default="sequential",
                    choices=("sequential", "bucketed", "masked"))
    args = ap.parse_args()

    results = {}
    for method in ("fedavg_s", "adaptcl"):
        sim = SimConfig(
            method=method,
            rounds=args.rounds,
            prune_interval=5,
            noniid_s=args.noniid,
            het=HeterogeneityConfig(sigma=args.sigma),
            engine=args.engine,
        )
        r = run_simulation(sim)
        results[method] = r
        print(f"[{method:9s}] best_acc={r.best_acc:.3f} time={r.total_time:.0f}s "
              f"param_red={r.param_reduction:.1%} "
              f"(host: {r.walltime_s:.1f}s, {r.recompiles} compiles, engine={r.engine})")
        if method == "adaptcl":
            print(f"            retentions={[round(g, 2) for g in r.retentions]}")
            hs = [f"{h:.2f}" for _, h in r.het_traj[:: max(1, args.rounds // 8)]]
            print(f"            heterogeneity trajectory: {' -> '.join(hs)}")

    fed, ada = results["fedavg_s"], results["adaptcl"]
    print(f"\nAdaptCL speedup: {fed.total_time / ada.total_time:.2f}x  "
          f"(paper at sigma=2: 1.78x)   dAcc={ada.best_acc - fed.best_acc:+.3f}")


if __name__ == "__main__":
    main()
