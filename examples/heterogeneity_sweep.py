"""Tab. IV reproduction driver: AdaptCL speedup vs heterogeneity level.

    PYTHONPATH=src python examples/heterogeneity_sweep.py [--rounds 16]
"""
import argparse

from repro.core.simulation import SimConfig, run_simulation
from repro.core.timing import HeterogeneityConfig, heterogeneity_closed_form


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=16)
    ap.add_argument("--sigmas", type=float, nargs="+", default=[2.0, 5.0, 10.0, 20.0])
    ap.add_argument("--engine", default="masked",
                    choices=("sequential", "bucketed", "masked"),
                    help="fleet engine; masked batches the sweep's local training")
    args = ap.parse_args()
    print(f"{'H(sigma)':>10s} {'speedup':>8s} {'dAcc':>8s} {'param_red':>10s}")
    for sigma in args.sigmas:
        fed = run_simulation(SimConfig(method="fedavg_s", rounds=args.rounds, engine=args.engine,
                                       noniid_s=80.0, het=HeterogeneityConfig(sigma=sigma)))
        ada = run_simulation(SimConfig(method="adaptcl", rounds=args.rounds, prune_interval=4, engine=args.engine,
                                       noniid_s=80.0, het=HeterogeneityConfig(sigma=sigma)))
        h = heterogeneity_closed_form(10, sigma)
        print(f"{h:6.2f}({sigma:>4.0f}) {fed.total_time/ada.total_time:7.2f}x "
              f"{ada.best_acc - fed.best_acc:+8.3f} {ada.param_reduction:9.1%}")
    print("(paper Tab. IV: 1.78x/3.15x/4.85x/6.20x at H=0.32/0.62/0.76/0.87)")


if __name__ == "__main__":
    main()
