"""Benchmark harness: one function per paper table (``name,value,derived`` CSV).

    PYTHONPATH=src python -m benchmarks.run [--only table2_main] [--quick]

Roofline rows are read from ``results/roofline_single.jsonl`` if the dry-run
sweep has been run (``python -m repro.launch.roofline --out ...``); the
simulator tables always run live.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def roofline_table(path="results/roofline_single.jsonl"):
    """§Roofline terms per (arch x shape), from the compiled dry-run."""
    if not os.path.exists(path):
        print(f"roofline/skipped,no {path} (run repro.launch.roofline first),")
        return
    seen = {}
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            seen[(rec["arch"], rec["shape"], rec.get("label", "baseline"))] = rec
    for (arch, shape, label), rec in sorted(seen.items()):
        if rec["status"] != "ok":
            print(f"roofline/{arch}/{shape}/{label},{rec['status']},{rec.get('reason','')[:60]}")
            continue
        print(
            f"roofline/{arch}/{shape}/{label},{rec['dominant']},"
            f"tc={rec['t_compute_s']*1e3:.1f}ms;tm={rec['t_memory_s']*1e3:.1f}ms;"
            f"tx={rec['t_collective_s']*1e3:.1f}ms;useful={rec['useful_flops_ratio']:.2f};"
            f"fits={rec['fits_hbm']}"
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--engine", default="sequential",
        choices=("sequential", "bucketed", "masked"),
        help="fleet engine for simulator local training (core.fleet)",
    )
    args = ap.parse_args()
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"
    os.environ["BENCH_ENGINE"] = args.engine

    from benchmarks import tables  # import after BENCH_QUICK is set

    benches = [
        ("table2_main", tables.table2_main),
        ("table4_heterogeneity", tables.table4_heterogeneity),
        ("fig2_principles", tables.fig2_principles),
        ("fig5_aggregation", tables.fig5_aggregation),
        ("fig8_convergence", tables.fig8_convergence),
        ("table14_interval", tables.table14_interval),
        ("table17_dgc", tables.table17_dgc),
        ("overhead", tables.overhead),
        ("engines", tables.engines),
        ("roofline_table", roofline_table),
    ]
    print("name,value,derived")
    for name, fn in benches:
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception as e:  # keep the harness going; a bench failure is data
            print(f"{name}/FAILED,{type(e).__name__},{str(e)[:120]}")
        print(f"{name}/_elapsed_s,{time.perf_counter() - t0:.1f},")


if __name__ == "__main__":
    main()
