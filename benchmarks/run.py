"""Benchmark harness: one function per paper table (``name,value,derived`` CSV).

    PYTHONPATH=src python -m benchmarks.run [--only table2_main] [--quick]
    PYTHONPATH=src python -m benchmarks.run scale [--quick] [--out BENCH_scale.json]
    PYTHONPATH=src python -m benchmarks.run async_scale [--quick] [--out BENCH_async.json]

``scale`` is the sync fleet-scaling bench: W in {10, 50, 200} x engine x
scenario, tracking host walltime / recompiles / host round-trips of the
resident masked engine against the sequential reference.  Results land in
``BENCH_scale.json`` so the perf trajectory is tracked across PRs.

``shard_scale`` is the mesh-sharded fleet bench: W x n_dev over the fused
sync engine on 8 virtual CPU devices, pinning host dispatches FLAT in device
count and bit-identical prune indices at every mesh size
(``BENCH_shard.json``).

``async_scale`` is the asynchronous analogue: W in {10, 50, 200} x scheduler
(fedasync_s / ssp_s / dcasgd_s) x participation C x engine {masked, fused}.
Rows split ``compile_walltime_s`` from steady walltime (like BENCH_fused /
BENCH_retention) and report steady events/sec; checks pin fused dispatch
counts strictly below the resident engine's in every cell, a >= 1.3x steady
events/sec speedup at the largest W, and zero host round-trips; at C=0.1
the W=200 walltime should stay within a small factor of W=50 because device
compute is sized to the C*W participants, not the slot pool.  Results land
in ``BENCH_async.json``.

Engine x scheduler support matrix (see README.md): every method runs on
``sequential``/``bucketed``/``masked``; the resident zero-round-trip path
(and participation-sized sub-stacks) is the ``masked`` engine, for both the
sync methods and the async schedulers.

Roofline rows are read from ``results/roofline_single.jsonl`` if the dry-run
sweep has been run (``python -m repro.launch.roofline --out ...``); the
simulator tables always run live.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def roofline_table(path="results/roofline_single.jsonl"):
    """§Roofline terms per (arch x shape), from the compiled dry-run."""
    if not os.path.exists(path):
        print(f"roofline/skipped,no {path} (run repro.launch.roofline first),")
        return
    seen = {}
    with open(path) as f:
        for line in f:
            rec = json.loads(line)
            seen[(rec["arch"], rec["shape"], rec.get("label", "baseline"))] = rec
    for (arch, shape, label), rec in sorted(seen.items()):
        if rec["status"] != "ok":
            print(f"roofline/{arch}/{shape}/{label},{rec['status']},{rec.get('reason','')[:60]}")
            continue
        print(
            f"roofline/{arch}/{shape}/{label},{rec['dominant']},"
            f"tc={rec['t_compute_s']*1e3:.1f}ms;tm={rec['t_memory_s']*1e3:.1f}ms;"
            f"tx={rec['t_collective_s']*1e3:.1f}ms;useful={rec['useful_flops_ratio']:.2f};"
            f"fits={rec['fits_hbm']}"
        )


def scale(out_path: str = "BENCH_scale.json", quick: bool = False) -> None:
    """Fleet-scaling bench: W x engine x scenario host-cost grid.

    The resident masked engine's host cost per round is ~flat in W (one
    device program + stacked aggregation), so W=200 stays within a small
    factor of W=10 — while the sequential reference pays W jit dispatches and
    2W extract/embed round-trips per round."""
    from repro.core.scenario import ScenarioConfig
    from repro.core.simulation import SimConfig, run_simulation
    from repro.core.timing import HeterogeneityConfig
    from repro.models.cnn import vgg_config

    cnn = vgg_config("vgg_scale", [16, "M", 32], num_classes=10, image_size=8)
    worker_counts = (4, 12) if quick else (10, 50, 200)
    rounds = 2 if quick else 3
    scenarios = {
        "full": None,
        "flaky": ScenarioConfig(
            participation=0.5, dropout=0.1, churn=0.02, seed=1
        ),
    }
    rows = []
    print("name,value,derived")
    for W in worker_counts:
        for engine in ("sequential", "masked"):
            for scen_name, scen in scenarios.items():
                r = run_simulation(SimConfig(
                    method="adaptcl", engine=engine, scenario=scen,
                    rounds=rounds, prune_interval=2, num_workers=W,
                    batch_size=8, cnn=cnn, eval_every=rounds,
                    het=HeterogeneityConfig(num_workers=W, sigma=5.0),
                    seed=7,
                ))
                rows.append(dict(
                    workers=W, engine=engine, scenario=scen_name,
                    rounds=rounds, walltime_s=r.walltime_s,
                    recompiles=r.recompiles, batched_calls=r.batched_calls,
                    host_roundtrips=r.host_roundtrips,
                    final_acc=r.final_acc, total_time=r.total_time,
                ))
                print(
                    f"scale/W{W}/{engine}/{scen_name},{r.walltime_s:.2f}s,"
                    f"recompiles={r.recompiles};roundtrips={r.host_roundtrips};"
                    f"batched={r.batched_calls};acc={r.final_acc:.3f}"
                )
    by = {(row["workers"], row["engine"], row["scenario"]): row for row in rows}
    lo, hi = worker_counts[0], worker_counts[-1]
    for scen_name in scenarios:
        ratio = (by[(hi, "masked", scen_name)]["walltime_s"]
                 / max(by[(lo, "masked", scen_name)]["walltime_s"], 1e-9))
        print(f"scale/masked_W{hi}_over_W{lo}/{scen_name},{ratio:.2f}x,"
              f"resident host cost ~flat in W (target < 3x)")
    with open(out_path, "w") as f:
        json.dump({"rows": rows, "worker_counts": list(worker_counts)}, f, indent=2)
    print(f"scale/json,{out_path},")


def async_scale(out_path: str = "BENCH_async.json", quick: bool = False) -> None:
    """Async fleet-scaling bench: W x scheduler x participation C x engine.

    Every cell runs with window batching and zero host round-trips; the
    resident masked engine pays one jit dispatch per window batch, while the
    fused engine (``core.fused.run_async_fused``) runs chunks of window
    batches as single ``lax.scan`` programs — O(events / round_fusion) host
    dispatches with bit-identical commit schedules (the fused driver hard
    errors on divergence).  Rows split ``compile_walltime_s`` (trace +
    compile + first execution) from steady walltime, so the fused speedup is
    measured on steady-state events/sec — the largest-W full-cohort cells
    run interleaved masked/fused repetitions and the check takes the median
    of per-pair speedups.  At C < 1 device compute tracks the C*W
    participants instead of the slot pool, and recompiles stay bounded by
    the bucket/signature count."""
    from repro.core.scenario import ScenarioConfig
    from repro.core.simulation import SimConfig, run_simulation
    from repro.core.timing import HeterogeneityConfig
    from repro.models.cnn import vgg_config

    cnn = vgg_config("vgg_ascale", [16, "M", 32], num_classes=10, image_size=8)
    worker_counts = (4, 12) if quick else (10, 50, 200)
    rounds = 2 if quick else 3
    parts = (1.0, 0.5) if quick else (1.0, 0.1)
    schedulers = ("fedasync_s", "ssp_s", "dcasgd_s")
    rows = []
    print("name,value,derived")

    def cell(engine, W, method, C):
        scen = None if C >= 1.0 else ScenarioConfig(participation=C, seed=1)
        n_part = W if C >= 1.0 else min(W, max(1, round(C * W)))
        r = run_simulation(SimConfig(
            method=method, engine=engine, scenario=scen,
            rounds=rounds, num_workers=W, batch_size=8, cnn=cnn,
            async_window=1000.0, eval_every=rounds,
            het=HeterogeneityConfig(num_workers=W, sigma=5.0),
            seed=7,
        ))
        assert r.host_roundtrips == 0, "resident async must not round-trip"
        events = n_part * rounds
        steady = max(r.walltime_s - r.compile_walltime_s, 1e-9)
        row = dict(
            workers=W, engine=engine, scheduler=method, participation=C,
            rounds=rounds, events=events, walltime_s=r.walltime_s,
            compile_walltime_s=r.compile_walltime_s,
            steady_walltime_s=steady,
            events_per_sec_steady=events / steady,
            host_dispatches=r.host_dispatches, fused_chunks=r.fused_chunks,
            recompiles=r.recompiles, batched_calls=r.batched_calls,
            bucket_sizes=r.bucket_sizes,
            host_roundtrips=r.host_roundtrips,
            final_acc=r.final_acc, total_time=r.total_time,
        )
        rows.append(row)
        print(
            f"async_scale/W{W}/{engine}/{method}/C{C},"
            f"{events / steady:.2f}eps,"
            f"wall={r.walltime_s:.2f}s;compile={r.compile_walltime_s:.2f}s;"
            f"dispatches={r.host_dispatches};recompiles={r.recompiles};"
            f"acc={r.final_acc:.3f}"
        )
        return row

    hi = worker_counts[-1]
    pair_speedups = {m: [] for m in schedulers}
    for W in worker_counts:
        for method in schedulers:
            for C in parts:
                rm = cell("masked", W, method, C)
                rf = cell("fused", W, method, C)
                if W == hi and C == 1.0:
                    pair_speedups[method].append(
                        rm["steady_walltime_s"] / rf["steady_walltime_s"]
                    )
    for _ in range(0 if quick else 2):   # extra interleaved reps (see doc)
        for method in schedulers:
            rm = cell("masked", hi, method, 1.0)
            rf = cell("fused", hi, method, 1.0)
            pair_speedups[method].append(
                rm["steady_walltime_s"] / rf["steady_walltime_s"]
            )

    by = {}
    for row in rows:   # first occurrence wins (reps re-measure walltime only)
        key = (row["workers"], row["engine"], row["scheduler"],
               row["participation"])
        by.setdefault(key, row)
    lo = worker_counts[-2]
    c_lo = min(parts)
    ratios = {}
    for method in schedulers:
        ratio = (by[(hi, "masked", method, c_lo)]["steady_walltime_s"]
                 / max(by[(lo, "masked", method, c_lo)]["steady_walltime_s"],
                       1e-9))
        ratios[method] = ratio
        print(f"async_scale/{method}_W{hi}_over_W{lo}/C{c_lo},{ratio:.2f}x,"
              f"participation-sized compute (target ~<1.5x)")
    speedup = {
        m: sorted(s)[len(s) // 2] for m, s in pair_speedups.items()
    }
    checks = {
        # fused must dispatch strictly fewer programs than resident in EVERY
        # cell — O(events/K) chunks + evals vs one dispatch per window batch
        "fused_dispatches_strictly_below_resident": all(
            by[(W, "fused", m, C)]["host_dispatches"]
            < by[(W, "masked", m, C)]["host_dispatches"]
            for W in worker_counts for m in schedulers for C in parts
        ),
        "steady_speedup_at_max_W": speedup,
        "steady_speedup_samples": pair_speedups,
        "steady_speedup_ge_1_3x": all(s >= 1.3 for s in speedup.values()),
        "walltime_ratio_hi_over_lo_at_min_C": ratios,
    }
    for k, v in checks.items():
        print(f"async_scale/{k},{v},")
    with open(out_path, "w") as f:
        json.dump({
            "rows": rows,
            "worker_counts": list(worker_counts),
            "participations": list(parts),
            "checks": checks,
        }, f, indent=2)
    print(f"async_scale/json,{out_path},")


def fused(out_path: str = "BENCH_fused.json", quick: bool = False) -> None:
    """Round-fusion bench: W x engine {masked, fused} rounds/sec grid.

    The fused engine runs chunks of rounds between prune-rate-learning
    events as ONE on-device lax.scan program (core.fused), so host
    dispatches drop from O(rounds) to O(rounds / round_fusion) and the
    per-round host tax (stack pulls, float64 aggregation, jit dispatch)
    disappears.  Steady-state rounds/sec excludes the first-call warm-up
    (``SimResult.compile_walltime_s``: trace + compile + one execution).
    Checks: at the largest W the fused engine does >= 3x the resident
    masked engine's steady rounds/sec, per-round prune indices are
    BIT-identical to the host path (``prune_events``), and final accuracy
    matches the sequential reference within 1e-3 at the smallest W.

    The cell keeps per-round device compute LEAN (tiny CNN, batch 4, one
    step per worker per round) so the round boundary — the cost this engine
    exists to remove: per-round jit dispatches, host<->device syncs, stack
    pulls, NumPy aggregation, host pruning — dominates the masked engine's
    round; compute-bound scaling is the retention_sweep bench's story.

    Per-round dispatch+sync latency is highly sensitive to host load (each
    masked round blocks on the device at least once; a fused chunk blocks
    once per ``round_fusion`` rounds), so single-shot walltimes are noisy.
    The largest-W cell therefore runs INTERLEAVED masked/fused repetitions
    and reports the median of per-pair speedups (all samples recorded in
    the JSON)."""
    from repro.core.simulation import SimConfig, run_simulation
    from repro.core.timing import HeterogeneityConfig
    from repro.models.cnn import vgg_config

    cnn = vgg_config("vgg_fuse", [4, "M", 8], num_classes=10, image_size=8)
    worker_counts = (4, 12) if quick else (10, 50, 200)
    rounds = 4 if quick else 20
    fusion = 2 if quick else 5
    rows = []
    prune_identical = {}

    def cell(engine, W, n_rounds, pi, **kw):
        r = run_simulation(SimConfig(
            method="adaptcl", engine=engine, rounds=n_rounds,
            prune_interval=pi, num_workers=W, batch_size=8,
            cnn=cnn, eval_every=n_rounds,
            het=HeterogeneityConfig(num_workers=W, sigma=5.0),
            seed=7, **kw,
        ))
        steady = max(r.walltime_s - r.compile_walltime_s, 1e-9)
        rows.append(dict(
            workers=W, engine=engine, rounds=n_rounds,
            round_fusion=kw.get("round_fusion", 0),
            walltime_s=r.walltime_s,
            compile_walltime_s=r.compile_walltime_s,
            steady_walltime_s=steady,
            rounds_per_sec_steady=n_rounds / steady,
            host_dispatches=r.host_dispatches,
            host_roundtrips=r.host_roundtrips,
            fused_chunks=r.fused_chunks,
            recompiles=r.recompiles, final_acc=r.final_acc,
        ))
        print(
            f"fused/W{W}/{engine}/R{n_rounds},{n_rounds / steady:.2f}rps,"
            f"wall={r.walltime_s:.2f}s;compile={r.compile_walltime_s:.2f}s;"
            f"dispatches={r.host_dispatches};recompiles={r.recompiles};"
            f"acc={r.final_acc:.3f}"
        )
        return r

    print("name,value,derived")
    # equivalence cell vs the SEQUENTIAL reference, at the test suite's
    # scale: accuracy over the 512-image test set is a step function
    # (1 image = 0.2%), so long runs accumulate legitimate cross-engine
    # float drift past a step — correctness is pinned on the short run
    # (and bit-identical prune indices hold at every scale below)
    eq_rounds = 3 if quick else 6
    r_seq = cell("sequential", worker_counts[0], eq_rounds, 2)
    r_feq = cell("fused", worker_counts[0], eq_rounds, 2, round_fusion=fusion)
    acc_gap_vs_sequential = abs(r_feq.final_acc - r_seq.final_acc)
    seq_prunes_identical = r_feq.prune_events == r_seq.prune_events

    # perf grid: resident masked vs fused, steady-state rounds/sec; the
    # largest W runs interleaved repetitions (see docstring)
    hi = worker_counts[-1]
    pair_speedups = []
    for W in worker_counts:
        reps = (5 if W == hi else 1) if not quick else 1
        for _ in range(reps):
            r_m = cell("masked", W, rounds, fusion)
            r_f = cell("fused", W, rounds, fusion, round_fusion=fusion)
            prune_identical[W] = r_f.prune_events == r_m.prune_events
            if W == hi:
                pair_speedups.append(
                    (r_m.walltime_s - r_m.compile_walltime_s)
                    / max(r_f.walltime_s - r_f.compile_walltime_s, 1e-9)
                )
    by = {(row["workers"], row["engine"], row["rounds"]): row for row in rows}
    speedup = sorted(pair_speedups)[len(pair_speedups) // 2]
    dispatch_ratio = (by[(hi, "masked", rounds)]["host_dispatches"]
                      / max(by[(hi, "fused", rounds)]["host_dispatches"], 1))
    checks = {
        "prune_indices_bit_identical": (
            all(prune_identical.values()) and seq_prunes_identical
        ),
        "steady_speedup_at_max_W": speedup,
        "steady_speedup_samples": pair_speedups,
        "steady_speedup_ge_3x": speedup >= 3.0,
        "dispatch_ratio_at_max_W": dispatch_ratio,
        # 2 accuracy evals (initial + final) x 2 test batches go through the
        # same counted jit cache for every engine; net of those, the fused
        # round loop dispatches one program per chunk
        "fused_dispatches_O_R_over_K": (
            by[(hi, "fused", rounds)]["host_dispatches"] - 4
            <= -(-rounds // fusion)
        ),
        "final_acc_gap_vs_sequential": acc_gap_vs_sequential,
        "final_acc_within_1e3_of_sequential": acc_gap_vs_sequential <= 1e-3,
    }
    for k, v in checks.items():
        print(f"fused/{k},{v},")
    with open(out_path, "w") as f:
        json.dump({
            "rows": rows,
            "worker_counts": list(worker_counts),
            "round_fusion": fusion,
            "checks": checks,
        }, f, indent=2)
    print(f"fused/json,{out_path},")


def shard_scale(out_path: str = "BENCH_shard.json", quick: bool = False) -> None:
    """Mesh-sharded fleet bench: W x n_dev grid over the fused sync engine.

    The sharded engine runs the fused ``lax.scan`` chunk as one shard_map
    program over the fleet mesh axis — per-shard ``[W_local, ...]`` stacks
    with two-tier aggregation (per-shard ``tensordot`` partial reduce +
    global ``psum``) — so host dispatches stay O(rounds / round_fusion)
    while W scales with device count.  CPU CI verifies the *economics*, not
    device speedups: the 8 "devices" are XLA virtual host devices sharing
    one physical CPU (``--xla_force_host_platform_device_count=8``), so
    sharding adds collective overhead without adding silicon.  Checks:

      * ``host_dispatches`` FLAT in n_dev at every W (identical to the
        single-device fused engine — sharding multiplies devices, never
        launches);
      * per-round prune indices BIT-identical across every mesh size;
      * steady rounds/sec at the largest W within a noise factor of the
        single-device fused engine (interleaved no-mesh/mesh repetitions,
        median of per-pair ratios — "not worse" modulo virtual-device
        collective tax; on real multi-device silicon the sharded engine is
        where W past single-HBM capacity comes from).

    Rows split ``compile_walltime_s`` from steady walltime like BENCH_fused.
    Requires >= 8 visible devices (``main()`` injects the XLA flag before
    jax loads when launched as ``python -m benchmarks.run shard_scale``)."""
    import jax

    from repro.core.simulation import SimConfig, run_simulation
    from repro.core.timing import HeterogeneityConfig
    from repro.launch.mesh import make_fleet_mesh
    from repro.models.cnn import vgg_config

    n_avail = len(jax.devices())
    if n_avail < 2:
        print("shard_scale/skipped,needs >= 2 devices "
              "(set XLA_FLAGS=--xla_force_host_platform_device_count=8),")
        return
    cnn = vgg_config("vgg_shard", [4, "M", 8], num_classes=10, image_size=8)
    worker_counts = (8,) if quick else (8, 64, 256)
    device_counts = tuple(d for d in (1, 2, 4, 8) if d <= n_avail)
    rounds = 4 if quick else 16
    fusion = 2 if quick else 4
    rows = []
    meshes = {d: make_fleet_mesh(d) for d in device_counts}
    print("name,value,derived")

    def cell(W, n_dev):
        mesh = None if n_dev == 0 else meshes[n_dev]
        r = run_simulation(SimConfig(
            method="adaptcl", engine="fused", rounds=rounds,
            prune_interval=fusion, round_fusion=fusion, num_workers=W,
            batch_size=8, cnn=cnn, eval_every=rounds, mesh=mesh,
            het=HeterogeneityConfig(num_workers=W, sigma=5.0),
            seed=7,
        ))
        assert r.host_roundtrips == 0
        steady = max(r.walltime_s - r.compile_walltime_s, 1e-9)
        rows.append(dict(
            workers=W, n_dev=r.n_devices if mesh is not None else 0,
            shard_spec=r.shard_spec, rounds=rounds, round_fusion=fusion,
            walltime_s=r.walltime_s,
            compile_walltime_s=r.compile_walltime_s,
            steady_walltime_s=steady,
            rounds_per_sec_steady=rounds / steady,
            host_dispatches=r.host_dispatches,
            fused_chunks=r.fused_chunks, recompiles=r.recompiles,
            final_acc=r.final_acc,
        ))
        print(
            f"shard_scale/W{W}/ndev{n_dev},{rounds / steady:.2f}rps,"
            f"wall={r.walltime_s:.2f}s;compile={r.compile_walltime_s:.2f}s;"
            f"dispatches={r.host_dispatches};spec={r.shard_spec};"
            f"acc={r.final_acc:.3f}"
        )
        return r

    hi = worker_counts[-1]
    prune_identical, dispatches_flat = [], []
    pair_ratios = []
    for W in worker_counts:
        base = cell(W, 0)   # single-device fused baseline (no mesh)
        for n_dev in device_counts:
            if W % n_dev:
                continue
            r = cell(W, n_dev)
            prune_identical.append(r.prune_events == base.prune_events)
            dispatches_flat.append(r.host_dispatches == base.host_dispatches)
    n_max = max(d for d in device_counts if hi % d == 0)
    for _ in range(1 if quick else 3):   # interleaved reps at the largest W
        r_b = cell(hi, 0)
        r_s = cell(hi, n_max)
        pair_ratios.append(
            (r_b.walltime_s - r_b.compile_walltime_s)
            / max(r_s.walltime_s - r_s.compile_walltime_s, 1e-9)
        )
    ratio = sorted(pair_ratios)[len(pair_ratios) // 2]
    checks = {
        "host_dispatches_flat_in_n_dev": all(dispatches_flat),
        "prune_indices_bit_identical": all(prune_identical),
        "steady_ratio_at_max_W": ratio,           # no-mesh steady / mesh steady
        "steady_ratio_samples": pair_ratios,
        # virtual host devices share one CPU: require the sharded engine to
        # stay within 2.5x of single-device steady throughput (measured
        # ~2.1x tax — the per-round psum crosses 8 XLA host "devices" with
        # no extra silicon behind them), not to beat it — throughput parity
        # and the capacity win need real multi-device hardware
        "steady_within_2_5x_of_single_device": ratio >= 0.4,
    }
    for k, v in checks.items():
        print(f"shard_scale/{k},{v},")
    with open(out_path, "w") as f:
        json.dump({
            "rows": rows,
            "worker_counts": list(worker_counts),
            "device_counts": list(device_counts),
            "round_fusion": fusion,
            "checks": checks,
        }, f, indent=2)
    print(f"shard_scale/json,{out_path},")


def retention_sweep(out_path: str = "BENCH_retention.json", quick: bool = False) -> None:
    """Device-FLOPs-vs-retention bench: compute path x retention grid.

    The paper's speedup claim is that a worker at retention r does ~r of the
    FLOPs; the dense masked engine can't show it (base-shape programs, masks
    are multiplies), the ``block_skip`` path must.  Each cell runs a resident
    adaptcl sim that prunes every worker to the target retention after round
    1 (index-prefix importance — the relabeled-CIG order that makes retained
    sets coordinate prefixes), then trains at it; we record walltime, the
    executed/ideal FLOPs ratio, and the kernel-grid block proxy.  Targets:
    blocks (and executed FLOPs) decrease monotonically with retention, and
    retention 0.25 executes < 0.5x the blocks of retention 1.0."""
    import numpy as np

    from repro.core.simulation import SimConfig, run_simulation
    from repro.data.synthetic import SyntheticImageTask
    from repro.models.cnn import vgg_config

    cnn = vgg_config("vgg_ret", [32, "M", 64], num_classes=10, image_size=8)
    task = SyntheticImageTask(num_classes=10, image_size=8, train_size=64,
                              test_size=64, seed=0)
    # rates realizing the target retentions under the index-prefix order
    targets = {1.0: 0.0, 0.5: 0.5, 0.25: 0.74, 0.125: 0.86}
    retentions = (1.0, 0.25) if quick else (1.0, 0.5, 0.25, 0.125)
    W, rounds = 2, 3
    rows = []
    print("name,value,derived")
    for compute in ("dense", "block_skip"):
        for target in retentions:
            r = run_simulation(SimConfig(
                method="adaptcl", engine="masked", compute=compute,
                compute_blocks=(128, 8, 8), importance="index",
                rounds=rounds, prune_interval=1, num_workers=W, batch_size=8,
                local_epochs=1.0, cnn=cnn, task=task, eval_every=rounds,
                fixed_pruned_rates=[[targets[target]] * W] + [[0.0] * W] * (rounds - 1),
                seed=3,
            ))
            rows.append(dict(
                compute=compute, retention_target=target,
                retention_realized=float(np.mean(r.retentions)),
                walltime_s=r.walltime_s,
                # warm-up (trace+compile+1st run) vs steady-state: the
                # retention=1.0 row's wall is mostly compile, not compute
                compile_walltime_s=r.compile_walltime_s,
                steady_walltime_s=r.walltime_s - r.compile_walltime_s,
                flops_executed=r.flops_executed, flops_ideal=r.flops_ideal,
                flops_ratio=r.flops_executed / max(r.flops_ideal, 1e-9),
                blocks_executed=r.blocks_executed,
                flops_per_image_final=r.flops_per_image_final,
                blocks_per_image_final=r.blocks_per_image_final,
                recompiles=r.recompiles, final_acc=r.final_acc,
            ))
            print(
                f"retention/{compute}/r{target},{r.walltime_s:.2f}s,"
                f"steady={rows[-1]['steady_walltime_s']:.2f}s;"
                f"compile={r.compile_walltime_s:.2f}s;"
                f"exec_over_ideal={rows[-1]['flops_ratio']:.3f};"
                f"blocks_final={r.blocks_per_image_final:.0f};acc={r.final_acc:.3f}"
            )
    # checks run on the steady-state per-image cost at the final sub-models —
    # warm-up rounds before the prune land in the cumulative ledger instead
    by = {(row["compute"], row["retention_target"]): row for row in rows}
    checks = {}
    bs_rows = [by[("block_skip", t)] for t in retentions]
    checks["blocks_monotone_decreasing"] = all(
        a["blocks_per_image_final"] >= b["blocks_per_image_final"]
        for a, b in zip(bs_rows, bs_rows[1:])
    )
    checks["flops_monotone_decreasing"] = all(
        a["flops_per_image_final"] >= b["flops_per_image_final"]
        for a, b in zip(bs_rows, bs_rows[1:])
    )
    lo = by[("block_skip", 0.25 if 0.25 in retentions else min(retentions))]
    hi = by[("block_skip", 1.0)]
    checks["quarter_blocks_over_full"] = (
        lo["blocks_per_image_final"] / max(hi["blocks_per_image_final"], 1e-9)
    )
    checks["quarter_under_half_blocks"] = checks["quarter_blocks_over_full"] < 0.5
    checks["dense_flops_flat_in_retention"] = (
        by[("dense", min(retentions))]["flops_per_image_final"]
        == by[("dense", 1.0)]["flops_per_image_final"]
    )
    for k, v in checks.items():
        print(f"retention/{k},{v},")
    with open(out_path, "w") as f:
        json.dump({"rows": rows, "retentions": list(retentions),
                   "checks": checks}, f, indent=2)
    print(f"retention/json,{out_path},")


def regrow_sweep(out_path: str = "BENCH_regrow.json", quick: bool = False) -> None:
    """Mask-regrowth bench: mask-dynamics variant x engine grid.

    AdaptCL's monotone pruning can strand a worker with a bad early mask;
    FedDST-style readjustment (``SimConfig.regrow``) prunes ``alpha_t`` of
    each worker's retained weight mass by global weight magnitude and grows
    the same param budget back by dense-gradient magnitude every
    ``interval`` rounds.  The grid runs prune-only against the cosine- and
    constant-schedule regrow variants on the masked and fused engines.

    The grid prunes with the ``no_adjacent`` shared-random order: regrowth
    earns its keep when the initial mask is POOR (a random order strands
    units the data cares about; readjustment recovers them by gradient
    magnitude).  Under the paper's frozen CIG ranking the initial mask is
    already near-optimal on this task and regrow is a wash — which is
    itself the FedDST finding: readjustment substitutes for a good prior
    ranking.

    Checks pin the PR's contract: the best regrow variant recovers at least
    the prune-only final accuracy, regrow events land in
    ``SimResult.prune_events`` (masked == fused BIT-identical, clocks
    exact), and the fused engine still runs O(rounds / round_fusion) chunks
    with recompiles bounded by the chunk + grow-gradient signatures (<= 2)
    — regrow boundaries align with the learning events here, so readjusting
    masks adds ZERO extra chunks."""
    from repro.core.simulation import RegrowConfig, SimConfig, run_simulation
    from repro.core.timing import HeterogeneityConfig
    from repro.models.cnn import vgg_config

    cnn = vgg_config("vgg_regrow", [16, "M", 32], num_classes=10, image_size=8)
    W = 5 if quick else 10
    rounds = 6 if quick else 16
    pi = 2 if quick else 4      # prune_interval == round_fusion == interval
    variants = {
        "prune_only": None,
        "regrow_cosine": RegrowConfig(interval=pi, alpha0=0.3,
                                      schedule="cosine"),
        "regrow_constant": RegrowConfig(interval=pi, alpha0=0.3,
                                        schedule="constant"),
    }
    rows = []
    results = {}
    print("name,value,derived")
    for vname, rg in variants.items():
        for engine in ("masked", "fused"):
            r = run_simulation(SimConfig(
                method="adaptcl", engine=engine, rounds=rounds,
                prune_interval=pi, round_fusion=pi, num_workers=W,
                batch_size=8, cnn=cnn, eval_every=rounds,
                het=HeterogeneityConfig(num_workers=W, sigma=5.0),
                seed=7, regrow=rg, importance="no_adjacent",
            ))
            results[(vname, engine)] = r
            event_rounds = sorted({t for t, _, _ in r.prune_events})
            rows.append(dict(
                variant=vname, engine=engine, rounds=rounds,
                round_fusion=pi, workers=W,
                final_acc=r.final_acc, total_time=r.total_time,
                comm_bytes=r.comm_bytes,
                prune_event_count=len(r.prune_events),
                prune_event_rounds=event_rounds,
                host_dispatches=r.host_dispatches,
                fused_chunks=r.fused_chunks, recompiles=r.recompiles,
                walltime_s=r.walltime_s,
                compile_walltime_s=r.compile_walltime_s,
            ))
            print(
                f"regrow/{vname}/{engine},acc={r.final_acc:.3f},"
                f"time={r.total_time:.1f};events={len(r.prune_events)};"
                f"dispatches={r.host_dispatches};recompiles={r.recompiles}"
            )

    prune_only_acc = results[("prune_only", "fused")].final_acc
    best_regrow_acc = max(
        results[(v, "fused")].final_acc
        for v in ("regrow_cosine", "regrow_constant")
    )
    fus = results[("regrow_cosine", "fused")]
    mas = results[("regrow_cosine", "masked")]
    checks = {
        # readjustment must not cost accuracy vs monotone pruning
        "best_regrow_acc": best_regrow_acc,
        "prune_only_acc": prune_only_acc,
        "regrow_acc_ge_prune_only": best_regrow_acc >= prune_only_acc,
        # regrow events recorded, and engines agree on them bit-for-bit
        "regrow_adds_events": all(
            len(results[(v, e)].prune_events)
            > len(results[("prune_only", e)].prune_events)
            for v in ("regrow_cosine", "regrow_constant")
            for e in ("masked", "fused")
        ),
        "events_bit_identical_masked_vs_fused": all(
            results[(v, "masked")].prune_events
            == results[(v, "fused")].prune_events
            for v in variants
        ),
        "clocks_identical_masked_vs_fused": all(
            results[(v, "masked")].total_time
            == results[(v, "fused")].total_time
            for v in variants
        ),
        # regrow boundaries align with learning events: still O(R/K) chunks,
        # and only the chunk + grow-gradient programs compile
        "fused_chunks_O_R_over_K": fus.fused_chunks == rounds // pi,
        # dispatches = chunks + evals + ONE grow-score gradient per regrow
        # event; evals are variant-independent, so the regrow overhead vs
        # prune-only is exactly the regrow event count
        "fused_dispatches_are_chunks_evals_and_grow_grads": (
            fus.host_dispatches - fus.fused_chunks
            - (len(fus.prune_events)
               - len(results[("prune_only", "fused")].prune_events))
            == results[("prune_only", "fused")].host_dispatches
            - results[("prune_only", "fused")].fused_chunks
        ),
        "fused_regrow_recompiles_le_2": fus.recompiles <= 2,
        "fused_dispatches_below_masked": (
            fus.host_dispatches < mas.host_dispatches
        ),
    }
    for k, v in checks.items():
        print(f"regrow/{k},{v},")
    with open(out_path, "w") as f:
        json.dump({
            "rows": rows,
            "rounds": rounds,
            "round_fusion": pi,
            "checks": checks,
        }, f, indent=2)
    print(f"regrow/json,{out_path},")


def world_model(out_path: str = "BENCH_world.json", quick: bool = False) -> None:
    """Fault-injection world-model bench: accuracy vs flakiness x engine.

    Runs the scripted fault families (core.faults) — capability drift,
    crash/recovery, a shard-aligned regional outage, a diurnal
    participation wave, and all four combined — on the masked and fused
    engines, and doubles as the regression harness for the fault layer:

    * ``faults=None`` vs an all-inactive ``FaultConfig()`` is BIT-identical
      (same prune events, clocks, accuracy: the overlay consumes zero
      extra RNG draws when off);
    * under every fault world masked == fused: exact virtual clocks,
      bit-identical prune events, identical fault ledgers, acc within
      1e-3;
    * fused dispatch economics survive the faults: crash/outage/wave ride
      in-scan (chunk count unchanged vs fault-free), only drift boundaries
      cut extra chunks, recompiles <= 2;
    * the accuracy-vs-flakiness grid is sane: no fault world beats the
      fault-free run by more than eval noise, and the outage world
      actually skipped starved rounds without hanging.
    """
    from repro.core.faults import (
        CrashConfig, DriftConfig, FaultConfig, OutageConfig, WaveConfig,
    )
    from repro.core.scenario import ScenarioConfig
    from repro.core.simulation import SimConfig, run_simulation
    from repro.core.timing import HeterogeneityConfig
    from repro.models.cnn import vgg_config

    cnn = vgg_config("vgg_world", [16, "M", 32], num_classes=10, image_size=8)
    W = 5 if quick else 10
    rounds = 6 if quick else 16
    pi = 2 if quick else 4      # prune_interval == round_fusion
    drift_round = pi + 1        # mid-interval: re-learning is drift-triggered
    dark = W // 2               # regional outage: slots [0, dark) go dark
    worlds = {
        "fault_free": dict(seed=3),
        "drift": dict(seed=3, faults=FaultConfig(
            drift=DriftConfig(worker=1, round=drift_round, factor=3.0))),
        "crash": dict(seed=3, faults=FaultConfig(
            crash=CrashConfig(rate=0.15, outage_rounds=2,
                              recovery_rounds=1))),
        "outage": dict(seed=3, min_participants=W - dark + 1,
                       faults=FaultConfig(outage=OutageConfig(
                           start=pi + 1, length=2, slot_lo=0,
                           slot_hi=dark))),
        "wave": dict(seed=3, participation=0.8, faults=FaultConfig(
            wave=WaveConfig(amplitude=0.5, period=max(2, rounds // 2)))),
        "combined": dict(seed=3, min_participants=2, participation=0.9,
                         faults=FaultConfig(
                             drift=DriftConfig(worker=0, round=drift_round,
                                               factor=2.0, mode="ramp",
                                               ramp_rounds=3),
                             crash=CrashConfig(rate=0.1),
                             outage=OutageConfig(start=rounds - 2, length=2,
                                                 slot_lo=0, slot_hi=dark),
                             wave=WaveConfig(amplitude=0.4,
                                             period=max(2, rounds // 2)))),
    }
    ledger_fields = ("drift_events", "rounds_degraded", "rounds_skipped",
                     "workers_recovered", "retry_total")

    def run(engine, scen_kw):
        return run_simulation(SimConfig(
            method="adaptcl", engine=engine, rounds=rounds,
            prune_interval=pi, round_fusion=pi, num_workers=W,
            batch_size=8, cnn=cnn, eval_every=rounds,
            het=HeterogeneityConfig(num_workers=W, sigma=5.0),
            seed=7, scenario=ScenarioConfig(**scen_kw),
        ))

    rows = []
    results = {}
    print("name,value,derived")
    for wname, scen_kw in worlds.items():
        for engine in ("masked", "fused"):
            r = run(engine, scen_kw)
            results[(wname, engine)] = r
            led = {f: getattr(r, f) for f in ledger_fields}
            rows.append(dict(
                world=wname, engine=engine, rounds=rounds, round_fusion=pi,
                workers=W, final_acc=r.final_acc, total_time=r.total_time,
                comm_bytes=r.comm_bytes,
                prune_event_count=len(r.prune_events),
                host_dispatches=r.host_dispatches,
                fused_chunks=r.fused_chunks, recompiles=r.recompiles,
                walltime_s=r.walltime_s,
                compile_walltime_s=r.compile_walltime_s,
                **led,
            ))
            print(
                f"world/{wname}/{engine},acc={r.final_acc:.3f},"
                f"time={r.total_time:.1f};skipped={r.rounds_skipped};"
                f"degraded={r.rounds_degraded};recovered={r.workers_recovered};"
                f"dispatches={r.host_dispatches};recompiles={r.recompiles}"
            )

    # the regression leg: an all-inactive FaultConfig must be invisible
    inert = run("fused", dict(seed=3, faults=FaultConfig()))
    free = results[("fault_free", "fused")]
    acc_free = free.final_acc
    acc_slack = 0.08            # eval noise band on this tiny fixture
    checks = {
        "faultfree_bit_identical": (
            inert.final_acc == acc_free
            and inert.total_time == free.total_time
            and inert.prune_events == free.prune_events
        ),
        # clocks / prune events / ledgers EXACT; accuracy within the eval
        # noise band (f32 device vs f64 host aggregation flips a handful of
        # boundary test examples on this fixture — the strict 1e-3 contract
        # lives in tests/test_faults.py on the 4-class fixture)
        "engines_equivalent": all(
            results[(wn, "masked")].total_time
            == results[(wn, "fused")].total_time
            and results[(wn, "masked")].prune_events
            == results[(wn, "fused")].prune_events
            and abs(results[(wn, "masked")].final_acc
                    - results[(wn, "fused")].final_acc) <= 0.02
            and all(getattr(results[(wn, "masked")], f)
                    == getattr(results[(wn, "fused")], f)
                    for f in ledger_fields)
            for wn in worlds
        ),
        # crash/outage/wave ride in-scan: chunk count == the fault-free
        # run's R/K; only drift boundaries may cut extras (ramp: <= 3)
        "fused_chunks_O_R_over_K": all(
            results[(wn, "fused")].fused_chunks == rounds // pi
            for wn in ("fault_free", "crash", "outage", "wave")
        ),
        "drift_cuts_bounded": (
            results[("drift", "fused")].fused_chunks <= rounds // pi + 1
            and results[("combined", "fused")].fused_chunks
            <= rounds // pi + 3
        ),
        "fused_recompiles_le_2": all(
            results[(wn, "fused")].recompiles <= 2 for wn in worlds
        ),
        # accuracy-vs-flakiness: a hostile world never BEATS the fault-free
        # run beyond eval noise, and the flakiest world still converges
        "acc_flakiness_guard": all(
            results[(wn, "fused")].final_acc <= acc_free + acc_slack
            for wn in worlds
        ),
        "faulty_worlds_still_converge": all(
            results[(wn, "fused")].final_acc >= 2.0 / cnn.num_classes
            for wn in worlds
        ),
        # each family left its signature in the ledger — and completed
        "drift_triggered_relearning": (
            results[("drift", "fused")].drift_events >= 1
        ),
        "crash_recovered_workers": (
            results[("crash", "fused")].workers_recovered >= 1
        ),
        "outage_skipped_not_hung": (
            results[("outage", "fused")].rounds_skipped >= 1
            and len(results[("outage", "fused")].scenario_rounds) == rounds
        ),
        "wave_varies_cohort": len({
            n for _, n, _, _ in results[("wave", "fused")].scenario_rounds
        }) > 1,
        "faultfree_ledger_zero": all(
            getattr(free, f) == 0 for f in ledger_fields
        ),
    }
    for k, v in checks.items():
        print(f"world/{k},{v},")
    with open(out_path, "w") as f:
        json.dump({
            "rows": rows,
            "rounds": rounds,
            "round_fusion": pi,
            "checks": checks,
        }, f, indent=2)
    print(f"world/json,{out_path},")


def robust_world(out_path: str = "BENCH_robust.json", quick: bool = False) -> None:
    """Robust-aggregation bench: Byzantine/lossy-channel worlds x defense.

    The adversarial counterpart of ``world_model``: a fixed 20% Byzantine
    cohort emitting ``-10 x delta`` commits (``ByzantineConfig``, mode
    ``scale``) and a lossy channel (drop / duplicate / corrupt delivery,
    ``ChannelConfig``) against the robust server layer
    (``RobustAggConfig``: per-commit norm clip, coordinate-wise trimmed
    mean, MAD-outlier quarantine).  Headline checks:

    * under plain-mean aggregation the Byzantine world COLLAPSES
      (accuracy near chance), while trimmed-mean + clip + quarantine
      recovers to >= mean + 10 points and within 5 points of fault-free;
    * masked == fused stays EXACT under every robust world — virtual
      clocks, prune events, and the full fault ledger (retries, lost /
      duplicate / corrupt commits, quarantined commits) bit-identical;
    * the fused engine still runs O(rounds / round_fusion) chunks with
      recompiles <= 2 — the whole attack -> defense -> aggregate round
      (``aggregation.robust_submission_step_jnp``) rides inside the
      ``lax.scan`` chunk;
    * the degenerate 1-device mesh runs the same trimmed-mean via
      ``all_gather``-along-fleet and lands BIT-identical global params to
      the no-mesh fused engine.
    """
    import numpy as np

    from repro.core.aggregation import QuarantineConfig, RobustAggConfig
    from repro.core.faults import ByzantineConfig, ChannelConfig, FaultConfig
    from repro.core.scenario import ScenarioConfig
    from repro.core.simulation import SimConfig, run_simulation
    from repro.core.timing import HeterogeneityConfig
    from repro.launch.mesh import make_fleet_mesh
    from repro.models.cnn import vgg_config

    cnn = vgg_config("vgg_robust", [16, "M", 32], num_classes=10, image_size=8)
    W = 5 if quick else 10
    rounds = 6 if quick else 16
    pi = 2 if quick else 4      # prune_interval == round_fusion
    byz_workers = tuple(range(max(1, W // 5)))   # fixed 20% compromised set
    byz = FaultConfig(byzantine=ByzantineConfig(
        workers=byz_workers, mode="scale", scale=-10.0))
    chan = FaultConfig(channel=ChannelConfig(
        drop=0.15, dup=0.15, corrupt=0.1, corrupt_std=10.0))
    # clip ~= the honest per-commit norm on this fixture (~1.0): attackers
    # get crushed to honest magnitude before the trim; probation outlasts
    # the run, so a quarantined slot never re-enters.  The long probation
    # also keeps the exact-ledger contract OFF the readmission boundary:
    # each engine's f32 training stream differs at the last bit, and a
    # strike decision within an ulp of the 3*MAD threshold would flip a
    # re-entry cycle — with no readmission churn the pinned fixture stays
    # strike-for-strike identical across engines
    defense = RobustAggConfig(
        clip=1.0, trim=0.2, quarantine=QuarantineConfig(probation=100))
    worlds = {
        "fault_free": (None, None),
        "byz_mean": (byz, None),
        "byz_robust": (byz, defense),
        "channel_mean": (chan, None),
        "channel_robust": (chan, defense),
    }
    ledger_fields = ("drift_events", "rounds_degraded", "rounds_skipped",
                     "workers_recovered", "retry_total", "byz_commits",
                     "lost_commits", "dup_commits", "corrupt_commits",
                     "quarantined_commits")

    def run(engine, faults, robust, mesh=None):
        return run_simulation(SimConfig(
            method="adaptcl", engine=engine, rounds=rounds,
            prune_interval=pi, round_fusion=pi, num_workers=W,
            batch_size=8, cnn=cnn, eval_every=rounds, mesh=mesh,
            het=HeterogeneityConfig(num_workers=W, sigma=5.0),
            seed=7, robust=robust,
            scenario=ScenarioConfig(seed=3, faults=faults),
        ))

    rows = []
    results = {}
    print("name,value,derived")
    for wname, (faults, robust) in worlds.items():
        for engine in ("masked", "fused"):
            r = run(engine, faults, robust)
            results[(wname, engine)] = r
            led = {f: getattr(r, f) for f in ledger_fields}
            rows.append(dict(
                world=wname, engine=engine, rounds=rounds, round_fusion=pi,
                workers=W, byz_workers=list(byz_workers),
                final_acc=r.final_acc, total_time=r.total_time,
                comm_bytes=r.comm_bytes,
                prune_event_count=len(r.prune_events),
                host_dispatches=r.host_dispatches,
                fused_chunks=r.fused_chunks, recompiles=r.recompiles,
                walltime_s=r.walltime_s,
                compile_walltime_s=r.compile_walltime_s,
                **led,
            ))
            print(
                f"robust/{wname}/{engine},acc={r.final_acc:.3f},"
                f"time={r.total_time:.1f};byz={r.byz_commits};"
                f"lost={r.lost_commits};dup={r.dup_commits};"
                f"corrupt={r.corrupt_commits};quar={r.quarantined_commits};"
                f"retries={r.retry_total};dispatches={r.host_dispatches};"
                f"recompiles={r.recompiles}"
            )

    # the mesh leg: degenerate 1-device mesh == no-mesh, bit for bit (the
    # trimmed mean all-gathers a row block of everything and must change
    # NOTHING); skipped only if jax has no devices at all
    mesh_r = run("fused", byz, defense, mesh=make_fleet_mesh(1))
    base_r = results[("byz_robust", "fused")]
    mesh_identical = (
        all(np.array_equal(base_r.global_params[k], mesh_r.global_params[k])
            for k in base_r.global_params)
        and mesh_r.prune_events == base_r.prune_events
        and mesh_r.total_time == base_r.total_time
        and all(getattr(mesh_r, f) == getattr(base_r, f)
                for f in ledger_fields)
    )

    free = results[("fault_free", "fused")].final_acc
    mean_acc = results[("byz_mean", "fused")].final_acc
    rob_acc = results[("byz_robust", "fused")].final_acc
    checks = {
        # the headline: mean collapses, the robust server recovers
        "byz_mean_acc": mean_acc,
        "byz_robust_acc": rob_acc,
        "fault_free_acc": free,
        "robust_ge_mean_plus_10pts": rob_acc >= mean_acc + 0.10,
        "robust_within_5pts_of_fault_free": rob_acc >= free - 0.05,
        "channel_robust_ge_mean_plus_10pts": (
            results[("channel_robust", "fused")].final_acc
            >= results[("channel_mean", "fused")].final_acc + 0.10
        ),
        "channel_robust_within_10pts_of_fault_free": (
            results[("channel_robust", "fused")].final_acc >= free - 0.10
        ),
        # engine equivalence stays EXACT under attack: clocks / prune
        # events / full fault ledger bit-identical, acc within eval noise
        "engines_equivalent": all(
            results[(wn, "masked")].total_time
            == results[(wn, "fused")].total_time
            and results[(wn, "masked")].prune_events
            == results[(wn, "fused")].prune_events
            and abs(results[(wn, "masked")].final_acc
                    - results[(wn, "fused")].final_acc) <= 0.02
            and all(getattr(results[(wn, "masked")], f)
                    == getattr(results[(wn, "fused")], f)
                    for f in ledger_fields)
            for wn in worlds
        ),
        # dispatch economics survive the robust layer: the whole
        # attack->defense->aggregate round rides in-scan
        "fused_chunks_O_R_over_K": all(
            results[(wn, "fused")].fused_chunks == rounds // pi
            for wn in worlds
        ),
        "fused_recompiles_le_2": all(
            results[(wn, "fused")].recompiles <= 2 for wn in worlds
        ),
        "mesh_1dev_bit_identical": mesh_identical,
        # each family left its ledger signature
        "byz_commits_counted": results[("byz_mean", "fused")].byz_commits > 0,
        "channel_ledger_active": (
            results[("channel_robust", "fused")].retry_total > 0
            and results[("channel_robust", "fused")].dup_commits > 0
            and results[("channel_robust", "fused")].corrupt_commits > 0
        ),
        "quarantine_fired": (
            results[("byz_robust", "fused")].quarantined_commits > 0
        ),
        "faultfree_ledger_zero": all(
            getattr(results[("fault_free", "fused")], f) == 0
            for f in ledger_fields
        ),
    }
    for k, v in checks.items():
        print(f"robust/{k},{v},")
    with open(out_path, "w") as f:
        json.dump({
            "rows": rows,
            "rounds": rounds,
            "round_fusion": pi,
            "byz_workers": list(byz_workers),
            "checks": checks,
        }, f, indent=2)
    print(f"robust/json,{out_path},")


def flaky_grid(out_path: str = "BENCH_world.json", quick: bool = False) -> None:
    """Flakiness grid: (participation C, dropout, churn) x engine sweep.

    Sweeps the scenario layer's three flakiness axes jointly and merges the
    grid into ``BENCH_world.json`` (next to the fault worlds) under a
    ``flaky_grid`` key, so accuracy-vs-flakiness is tracked in one file.
    Checks: masked == fused stays exact in EVERY cell (clocks + prune
    events bit-identical, acc within eval noise), the clean cell matches
    the scenario-free baseline, every cell still converges past chance,
    and no flaky cell beats the clean cell by more than eval noise."""
    from repro.core.scenario import ScenarioConfig
    from repro.core.simulation import SimConfig, run_simulation
    from repro.core.timing import HeterogeneityConfig
    from repro.models.cnn import vgg_config

    cnn = vgg_config("vgg_flaky", [16, "M", 32], num_classes=10, image_size=8)
    W = 5 if quick else 10
    rounds = 6 if quick else 16
    pi = 2 if quick else 4
    parts = (1.0, 0.5)
    dropouts = (0.0, 0.2)
    churns = (0.0,) if quick else (0.0, 0.05)

    def run(engine, scen):
        return run_simulation(SimConfig(
            method="adaptcl", engine=engine, rounds=rounds,
            prune_interval=pi, round_fusion=pi, num_workers=W,
            batch_size=8, cnn=cnn, eval_every=rounds,
            het=HeterogeneityConfig(num_workers=W, sigma=5.0),
            seed=7, scenario=scen,
        ))

    rows = []
    results = {}
    print("name,value,derived")
    base = run("fused", None)
    for C in parts:
        for drop in dropouts:
            for churn in churns:
                scen = ScenarioConfig(
                    participation=C, dropout=drop, churn=churn, seed=3)
                for engine in ("masked", "fused"):
                    r = run(engine, scen)
                    results[(C, drop, churn, engine)] = r
                    rows.append(dict(
                        participation=C, dropout=drop, churn=churn,
                        engine=engine, rounds=rounds, workers=W,
                        final_acc=r.final_acc, total_time=r.total_time,
                        rounds_skipped=r.rounds_skipped,
                        host_dispatches=r.host_dispatches,
                        fused_chunks=r.fused_chunks,
                        recompiles=r.recompiles,
                    ))
                    print(
                        f"flaky/C{C}/d{drop}/ch{churn}/{engine},"
                        f"acc={r.final_acc:.3f},"
                        f"time={r.total_time:.1f};"
                        f"dispatches={r.host_dispatches};"
                        f"recompiles={r.recompiles}"
                    )

    cells = [(C, d, ch) for C in parts for d in dropouts for ch in churns]
    clean = results[(1.0, 0.0, 0.0, "fused")]
    acc_slack = 0.08            # eval noise band on this fixture
    checks = {
        "engines_equivalent": all(
            results[c + ("masked",)].total_time
            == results[c + ("fused",)].total_time
            and results[c + ("masked",)].prune_events
            == results[c + ("fused",)].prune_events
            and abs(results[c + ("masked",)].final_acc
                    - results[c + ("fused",)].final_acc) <= 0.02
            for c in cells
        ),
        # a full-participation zero-flakiness scenario is the baseline
        "clean_cell_matches_no_scenario": (
            clean.final_acc == base.final_acc
            and clean.total_time == base.total_time
            and clean.prune_events == base.prune_events
        ),
        "all_cells_converge": all(
            results[c + ("fused",)].final_acc >= 2.0 / cnn.num_classes
            for c in cells
        ),
        "acc_flakiness_guard": all(
            results[c + ("fused",)].final_acc
            <= clean.final_acc + acc_slack
            for c in cells
        ),
        "fused_recompiles_le_2": all(
            results[c + ("fused",)].recompiles <= 2 for c in cells
        ),
    }
    for k, v in checks.items():
        print(f"flaky/{k},{v},")
    blob = {}
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                blob = json.load(f)
        except (OSError, ValueError):
            blob = {}
    blob["flaky_grid"] = {
        "rows": rows, "rounds": rounds,
        "participations": list(parts), "dropouts": list(dropouts),
        "churns": list(churns), "checks": checks,
    }
    with open(out_path, "w") as f:
        json.dump(blob, f, indent=2)
    print(f"flaky/json,{out_path},")


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument(
        "command", nargs="?", default="tables",
        choices=("tables", "scale", "async_scale", "retention_sweep", "fused",
                 "shard_scale", "regrow_sweep", "world_model", "robust_world",
                 "flaky_grid"),
        help="'tables' (default) = paper-table benches; 'scale' = sync "
             "fleet-scaling grid (W x engine x scenario -> BENCH_scale.json); "
             "'async_scale' = resident async scheduler grid (W x scheduler x "
             "participation C -> BENCH_async.json); 'retention_sweep' = "
             "device FLOPs vs retention, dense vs block_skip "
             "(-> BENCH_retention.json); 'fused' = round-fusion rounds/sec + "
             "host-dispatch grid, masked vs fused (-> BENCH_fused.json); "
             "'shard_scale' = mesh-sharded fused engine, W x n_dev grid on 8 "
             "virtual CPU devices (-> BENCH_shard.json); 'regrow_sweep' = "
             "FedDST mask-readjustment variants x engine "
             "(-> BENCH_regrow.json); 'world_model' = fault-injection "
             "accuracy-vs-flakiness grid x engine (-> BENCH_world.json); "
             "'robust_world' = Byzantine/lossy-channel worlds vs the robust "
             "aggregation layer (-> BENCH_robust.json); 'flaky_grid' = "
             "(C, dropout, churn) sweep merged into BENCH_world.json",
    )
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None,
                    help="output JSON for 'scale' (default BENCH_scale.json) "
                         "/ 'async_scale' (default BENCH_async.json)")
    ap.add_argument(
        "--engine", default="sequential",
        choices=("sequential", "bucketed", "masked"),
        help="fleet engine for simulator local training (core.fleet)",
    )
    args = ap.parse_args()
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"
    os.environ["BENCH_ENGINE"] = args.engine

    if args.command == "shard_scale":
        # the virtual-device flag must land before jax initialises its
        # backend — run.py imports jax lazily inside the bench functions,
        # so injecting here is early enough when launched as a script
        flag = "--xla_force_host_platform_device_count=8"
        if "jax" not in sys.modules and flag.split("=")[0] not in os.environ.get(
            "XLA_FLAGS", ""
        ):
            os.environ["XLA_FLAGS"] = (
                os.environ.get("XLA_FLAGS", "") + " " + flag
            ).strip()
        shard_scale(args.out or "BENCH_shard.json", quick=args.quick)
        return
    if args.command == "scale":
        scale(args.out or "BENCH_scale.json", quick=args.quick)
        return
    if args.command == "async_scale":
        async_scale(args.out or "BENCH_async.json", quick=args.quick)
        return
    if args.command == "retention_sweep":
        retention_sweep(args.out or "BENCH_retention.json", quick=args.quick)
        return
    if args.command == "fused":
        fused(args.out or "BENCH_fused.json", quick=args.quick)
        return
    if args.command == "regrow_sweep":
        regrow_sweep(args.out or "BENCH_regrow.json", quick=args.quick)
        return
    if args.command == "world_model":
        world_model(args.out or "BENCH_world.json", quick=args.quick)
        return
    if args.command == "robust_world":
        robust_world(args.out or "BENCH_robust.json", quick=args.quick)
        return
    if args.command == "flaky_grid":
        flaky_grid(args.out or "BENCH_world.json", quick=args.quick)
        return

    from benchmarks import tables  # import after BENCH_QUICK is set

    benches = [
        ("table2_main", tables.table2_main),
        ("table4_heterogeneity", tables.table4_heterogeneity),
        ("fig2_principles", tables.fig2_principles),
        ("fig5_aggregation", tables.fig5_aggregation),
        ("fig8_convergence", tables.fig8_convergence),
        ("table14_interval", tables.table14_interval),
        ("table17_dgc", tables.table17_dgc),
        ("overhead", tables.overhead),
        ("engines", tables.engines),
        ("roofline_table", roofline_table),
    ]
    print("name,value,derived")
    for name, fn in benches:
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        try:
            fn()
        except Exception as e:  # keep the harness going; a bench failure is data
            print(f"{name}/FAILED,{type(e).__name__},{str(e)[:120]}")
        print(f"{name}/_elapsed_s,{time.perf_counter() - t0:.1f},")


if __name__ == "__main__":
    main()
