"""One benchmark per paper table/figure (AdaptCL, Tab. II-IV/XIV, Fig. 2/5/8).

All experiments run on synthetic classification tasks (no datasets ship
offline — DESIGN.md §7): claims are validated as *orderings and ratios*
against the paper's own update-time model (Eq. 6-8), not absolute CIFAR
numbers.  Rounds are scaled T=150 -> ~20, PI=10 -> 5 to fit the CPU budget;
the pruned-rate dynamics equalize update times within 3-4 prunings either
way (paper Fig. 9).
"""
from __future__ import annotations

import os
from typing import Dict, List

import numpy as np

from repro.core.pruned_rate import PrunedRateConfig
from repro.core.simulation import SimConfig, SimResult, run_simulation
from repro.core.timing import HeterogeneityConfig, heterogeneity_closed_form

QUICK = os.environ.get("BENCH_QUICK", "") == "1"
ENGINE = os.environ.get("BENCH_ENGINE", "sequential")   # core.fleet engine
ROUNDS = 8 if QUICK else 12
PI = 4 if QUICK else 5


def _row(name: str, value, derived: str = ""):
    print(f"{name},{value},{derived}")


def _run(method: str, sigma: float = 2.0, noniid: float = 0.0, **kw) -> SimResult:
    base = dict(
        method=method,
        rounds=ROUNDS,
        prune_interval=PI,
        noniid_s=noniid,
        het=HeterogeneityConfig(sigma=sigma),
        engine=ENGINE,
        seed=7,
    )
    base.update(kw)
    return run_simulation(SimConfig(**base))


def table2_main() -> Dict[str, SimResult]:
    """Tab. II analogue: six frameworks, IID + Non-IID(s=80)."""
    methods = ["fedavg", "fedavg_s", "fedasync_s", "ssp_s", "dcasgd_s", "adaptcl"]
    out = {}
    for dist, s in (("iid", 0.0), ("noniid", 80.0)):
        for m in methods:
            r = _run(m, noniid=s)
            out[f"{m}_{dist}"] = r
            _row(
                f"table2/{dist}/{m}/acc", f"{r.best_acc:.4f}",
                f"time_s={r.total_time:.1f};final={r.final_acc:.4f}",
            )
    for dist in ("iid", "noniid"):
        fed, ada = out[f"fedavg_s_{dist}"], out[f"adaptcl_{dist}"]
        _row(
            f"table2/{dist}/adaptcl_speedup", f"{fed.total_time / ada.total_time:.2f}x",
            f"dacc={ada.best_acc - fed.best_acc:+.4f};param_red={ada.param_reduction:.2%}",
        )
    return out


def table4_heterogeneity():
    """Tab. IV analogue: speedup/acc vs heterogeneity sigma (Non-IID)."""
    for sigma in (2.0, 5.0, 10.0, 20.0):
        fed = _run("fedavg_s", sigma=sigma, noniid=80.0)
        ada = _run("adaptcl", sigma=sigma, noniid=80.0,
                   rate_cfg=PrunedRateConfig(rho_max=0.5, gamma_min=0.1))
        h = heterogeneity_closed_form(10, sigma)
        _row(
            f"table4/H{h:.2f}/speedup", f"{fed.total_time / ada.total_time:.2f}x",
            f"sigma={sigma};dacc={ada.best_acc - fed.best_acc:+.4f};"
            f"param_red={ada.param_reduction:.2%}",
        )


def fig2_principles():
    """Fig. 2 analogue: distributed-pruning principles, Non-IID(s=80).

    Fixed pruned rates (Tab. IX protocol) isolate the pruning criterion."""
    from repro.core.masks import similarity

    rates = [[0.4, 0.3, 0.3, 0.2, 0.2, 0.2, 0.1, 0.1, 0.1, 0.0]] * 2
    rows = {}
    for crit in ("cig_bnscalor", "index", "no_adjacent", "no_identical",
                 "no_constant", "l1", "taylor", "fpgm", "hrank"):
        r = _run("adaptcl", noniid=80.0, importance=crit, fixed_pruned_rates=rates)
        sim_last = r.similarity_traj[-1][1] if r.similarity_traj else float("nan")
        rows[crit] = r
        _row(f"fig2/{crit}/acc", f"{r.best_acc:.4f}", f"similarity={sim_last:.3f}")
    # orderings the paper reports
    ok1 = rows["no_identical"].best_acc <= rows["index"].best_acc + 0.02
    ok2 = rows["cig_bnscalor"].best_acc >= rows["hrank"].best_acc - 0.02
    _row("fig2/identical_matters", ok1, "no_identical <= index (+tol)")
    _row("fig2/cig_beats_datadep", ok2, "cig >= hrank (-tol)")


def fig5_aggregation():
    """Fig. 5 analogue: By-worker vs By-unit, and pruning position beta."""
    rates = [[0.4, 0.3, 0.3, 0.2, 0.2, 0.2, 0.1, 0.1, 0.1, 0.0]]
    for agg in ("by_worker", "by_unit"):
        r = _run("adaptcl", noniid=80.0, aggregation=agg, fixed_pruned_rates=rates)
        _row(f"fig5/{agg}/acc", f"{r.best_acc:.4f}", f"final={r.final_acc:.4f}")
    for beta in (0.0, 0.5, 1.0):
        r = _run("adaptcl", noniid=80.0, beta=beta, fixed_pruned_rates=rates)
        _row(f"fig5/beta{beta}/acc", f"{r.best_acc:.4f}")


def fig8_convergence():
    """Fig. 8/9 analogue: update-time heterogeneity collapses within a few
    pruning intervals, for several starting heterogeneities."""
    for sigma in (2.0, 10.0):
        r = _run("adaptcl", sigma=sigma)
        h0 = r.het_traj[0][1]
        h_end = np.mean([h for _, h in r.het_traj[-3:]])
        phis_last = r.update_times[-1]
        _row(
            f"fig8/sigma{sigma}/het", f"{h0:.3f}->{h_end:.3f}",
            f"spread_end={max(phis_last)/min(phis_last):.2f}x",
        )


def table14_interval():
    """Tab. XIV analogue: pruning interval PI sensitivity."""
    for pi in (2, PI):
        r = _run("adaptcl", noniid=80.0, prune_interval=pi)
        _row(f"table14/PI{pi}/acc", f"{r.best_acc:.4f}", f"time_s={r.total_time:.1f}")


def table17_dgc():
    """Appendix E Tab. XVII: AdaptCL + DGC weight-delta compression."""
    for sparsity in (0.0, 0.7, 0.9):
        r = _run("adaptcl", noniid=80.0, dgc_sparsity=sparsity)
        _row(f"table17/dgc{sparsity}/acc", f"{r.best_acc:.4f}",
             f"time_s={r.total_time:.1f};comm_GB={r.comm_bytes/1e9:.3f}")


def overhead():
    """§IV-B overhead claims: server compute, index communication, recompiles."""
    r = _run("adaptcl")
    _row("overhead/server_s", f"{r.server_overhead_s:.3f}",
         f"wall_s={r.walltime_s:.1f};fraction_of_sim_time={r.server_overhead_s / max(r.total_time, 1e-9):.4f}")
    _row("overhead/recompiles", r.recompiles,
         f"jit (param-shape;shard;plan)-signatures compiled;engine={r.engine}")
    _row("overhead/comm_GB", f"{r.comm_bytes/1e9:.3f}", "payload incl. global-index ids")


def engines():
    """Fleet-engine host cost: same simulation, three local-training engines.

    The paper claim this backs is systemic, not statistical: heterogeneous
    sub-models need not serialize host training — masked batching runs the
    whole fleet as one device program with zero reconfigure-recompiles."""
    base = None
    for engine in ("sequential", "bucketed", "masked"):
        r = _run("adaptcl", noniid=80.0, engine=engine)
        if base is None:
            base = r
        _row(
            f"engines/{engine}/walltime_s", f"{r.walltime_s:.2f}",
            f"recompiles={r.recompiles};batched_calls={r.batched_calls};"
            f"roundtrips={r.host_roundtrips};"
            f"speedup_vs_seq={base.walltime_s / max(r.walltime_s, 1e-9):.2f}x;"
            f"final_acc={r.final_acc:.4f}",
        )
