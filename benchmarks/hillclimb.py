"""§Perf hillclimbing: hypothesis -> change -> measure on the three chosen
pairs (see EXPERIMENTS.md §Perf for the napkin math + verdicts).

  1. llama4-maverick-400b-a17b x train_4k   (does not fit; worst MoE pair)
  2. xlstm-1.3b x train_4k                  (the collective-bound train pair)
  3. internlm2-1.8b x train_4k              (representative; + the paper's own
     technique: retention sweep = NetworkReconfigure at production scale)

Run: PYTHONPATH=src python -m benchmarks.hillclimb [--pair N] [--out f.jsonl]
"""
import argparse
import json

from repro.launch.roofline import analyze_pair


def run(recs, out):
    for kw in recs:
        try:
            rec = analyze_pair(**kw)
        except Exception as e:
            rec = {**kw, "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
        if rec["status"] == "ok":
            print(
                f"[hillclimb] {rec['arch']} x {rec['shape']} [{rec['label']}]: "
                f"dom={rec['dominant']} tc={rec['t_compute_s']:.2f}s "
                f"tm={rec['t_memory_s']:.2f}s tx={rec['t_collective_s']:.2f}s "
                f"temp={rec['temp_bytes']/2**30:.1f}GiB args={rec['arg_bytes']/2**30:.1f}GiB "
                f"fits={rec['fits_hbm']} useful={rec['useful_flops_ratio']:.2f}"
            )
        else:
            print(f"[hillclimb] {kw.get('arch')} [{kw.get('label')}]: {rec['status']} {rec.get('error','')[:120]}")
        if out:
            with open(out, "a") as f:
                f.write(json.dumps(rec) + "\n")


PAIRS = {
    1: [  # llama4 train_4k: memory-dominated, does not fit
        dict(arch="llama4-maverick-400b-a17b", shape_name="train_4k", label="ll4-1-seqshard", seq_shard=True),
        dict(arch="llama4-maverick-400b-a17b", shape_name="train_4k", label="ll4-2-seqshard+bf16opt", seq_shard=True, opt_dtype="bfloat16"),
        dict(arch="llama4-maverick-400b-a17b", shape_name="train_4k", label="ll4-3-seqshard+bf16opt+mb4", seq_shard=True, opt_dtype="bfloat16", microbatch=4),
        dict(arch="llama4-maverick-400b-a17b", shape_name="train_4k", label="ll4-4-seqshard+bf16opt+mb16", seq_shard=True, opt_dtype="bfloat16", microbatch=16),
    ],
    2: [  # xlstm train_4k: collective-dominated
        dict(arch="xlstm-1.3b", shape_name="train_4k", label="xl-1-seqshard", seq_shard=True),
        dict(arch="xlstm-1.3b", shape_name="train_4k", label="xl-2-seqshard+mb2", seq_shard=True, microbatch=2),
        dict(arch="xlstm-1.3b", shape_name="train_4k", label="xl-3-fulldp", full_dp=True),
        dict(arch="xlstm-1.3b", shape_name="train_4k", label="xl-4-fulldp+mb2", full_dp=True, microbatch=2),
    ],
    3: [  # internlm2 train_4k: representative + the paper's technique
        dict(arch="internlm2-1.8b", shape_name="train_4k", label="il2-1-seqshard", seq_shard=True),
        dict(arch="internlm2-1.8b", shape_name="train_4k", label="il2-2-seqshard+mb2", seq_shard=True, microbatch=2),
        # paper-faithful: reconfigured sub-models (AdaptCL NetworkReconfigure)
        dict(arch="internlm2-1.8b", shape_name="train_4k", label="il2-paper-gamma0.6", retention=0.6),
        dict(arch="internlm2-1.8b", shape_name="train_4k", label="il2-paper-gamma0.3", retention=0.3),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", type=int, default=None)
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    args = ap.parse_args()
    pairs = [args.pair] if args.pair else sorted(PAIRS)
    for p in pairs:
        run(PAIRS[p], args.out)


if __name__ == "__main__":
    main()
