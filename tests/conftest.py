"""Shared fixtures: every test starts from the same global RNG state, so
stochastic helpers that fall back to the global generators are repeatable."""
import random

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed_global_rngs():
    random.seed(0)
    np.random.seed(0)
