"""Shared fixtures: every test starts from the same global RNG state, so
stochastic helpers that fall back to the global generators are repeatable.

This conftest also forces 8 virtual CPU devices (via ``XLA_FLAGS``) so the
mesh-sharded fleet tests (``test_sharded_fleet.py``) can build a real
multi-device mesh on CPU-only CI.  The flag must land in the environment
*before* jax initialises its backend, hence the import-time injection — it
is skipped if jax is already imported (e.g. under an embedding runner), in
which case mesh tests that need 8 devices skip themselves.
"""
import os
import random
import sys

import numpy as np
import pytest

_FORCE_DEVICES = "--xla_force_host_platform_device_count=8"
if "jax" not in sys.modules and _FORCE_DEVICES.split("=")[0] not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FORCE_DEVICES
    ).strip()


@pytest.fixture(autouse=True)
def _seed_global_rngs():
    random.seed(0)
    np.random.seed(0)


@pytest.fixture
def eight_devices():
    """Require the 8 virtual CPU devices the conftest requests; skip if the
    backend was initialised before the flag could take effect."""
    import jax

    if len(jax.devices()) < 8:
        pytest.skip("needs 8 visible devices (XLA_FLAGS took no effect)")
    return jax.devices()[:8]
