"""Fused async event-queue engine contracts (core.fused.run_async_fused).

Pins down:
  * fused == resident == per-worker equivalence for all three async
    schedulers — identical virtual clocks and eval schedules, staleness
    merge schedules bit-identical by plan construction (the fused driver
    hard-errors if the device pop diverges), final params within 1e-3;
  * the device sorted-queue pop (``async_pop_perm``) vs the host heap —
    exact ``(finish_time, worker_index)`` ordering including tie-breaks,
    golden-pinned with a uniform-phi fleet where EVERY first-wave finish
    ties;
  * host-dispatch economics: fused async runs launch O(events /
    round_fusion) jitted programs with recompiles <= 2, strictly below the
    resident engine's O(events);
  * dropout under async (timed-out commits): a golden event schedule at a
    fixed seed, engine-identical outcomes, and the churn rejection naming
    only churn.
"""
import numpy as np
import pytest

from repro.core.fused import async_pop_perm, split_time_keys
from repro.core.scenario import ScenarioConfig, ScenarioEngine
from repro.core.simulation import (
    SimConfig,
    _Env,
    _plan_async_events,
    run_simulation,
)
from repro.core.timing import HeterogeneityConfig
from repro.models.cnn import vgg_config

TINY = vgg_config("vgg_tiny_afu", [8, "M", 16], num_classes=4, image_size=8)


def _cfg(engine, method="fedasync_s", **kw):
    W = kw.pop("num_workers", 4)
    base = dict(
        method=method,
        engine=engine,
        rounds=2,
        num_workers=W,
        batch_size=16,
        cnn=TINY,
        het=HeterogeneityConfig(num_workers=W, sigma=kw.pop("sigma", 3.0)),
        eval_every=2,
        seed=5,
    )
    base.update(kw)
    return SimConfig(**base)


def _assert_async_equivalent(ref, fus):
    # identical virtual clocks: total time and every eval's (clock, ...) pair
    assert ref.total_time == fus.total_time
    assert len(ref.acc_time) == len(fus.acc_time)
    for (tr, _), (tf, _) in zip(ref.acc_time, fus.acc_time):
        assert tr == tf
    assert ref.comm_bytes == fus.comm_bytes
    assert ref.scenario_rounds == fus.scenario_rounds
    for k in ref.global_params:
        # rtol covers dcasgd's large-magnitude compensated updates, where
        # f32-vs-f64 merge drift scales with the element (still ~1e-6 rel)
        np.testing.assert_allclose(
            np.asarray(ref.global_params[k], np.float32),
            np.asarray(fus.global_params[k], np.float32),
            atol=1e-3, rtol=1e-5, err_msg=k,
        )


# ---------------------------------------------------------------------------
# equivalence: fused == resident == per-worker
# ---------------------------------------------------------------------------

def test_fused_async_matches_resident_quick():
    res = run_simulation(_cfg("masked"))
    fus = run_simulation(_cfg("fused"))
    _assert_async_equivalent(res, fus)
    assert fus.host_roundtrips == 0
    assert fus.fused_chunks >= 1


@pytest.mark.slow
@pytest.mark.parametrize("method", ["fedasync_s", "ssp_s", "dcasgd_s"])
@pytest.mark.parametrize("window", [0.0, 50.0])
def test_fused_async_engine_equivalence(method, window):
    kw = dict(method=method, async_window=window, rounds=3, num_workers=6)
    seq = run_simulation(_cfg("sequential", **kw))
    res = run_simulation(_cfg("masked", **kw))
    fus = run_simulation(_cfg("fused", **kw))
    _assert_async_equivalent(seq, fus)
    _assert_async_equivalent(res, fus)
    assert fus.host_roundtrips == 0
    assert seq.host_roundtrips >= 6 * 3       # per-commit merges round-trip


@pytest.mark.slow
@pytest.mark.parametrize("method", ["fedasync_s", "ssp_s", "dcasgd_s"])
def test_fused_async_dropout_and_sampling_equivalence(method):
    scen = ScenarioConfig(participation=0.75, dropout=0.4, seed=3)
    kw = dict(method=method, scenario=scen)
    seq = run_simulation(_cfg("sequential", **kw))
    res = run_simulation(_cfg("masked", **kw))
    fus = run_simulation(_cfg("fused", **kw))
    _assert_async_equivalent(seq, fus)
    _assert_async_equivalent(res, fus)


# ---------------------------------------------------------------------------
# device queue pop: host-heap-exact ordering incl. tie-breaks
# ---------------------------------------------------------------------------

def test_async_pop_perm_breaks_ties_by_worker():
    hi, lo = split_time_keys(np.asarray([5.0, 3.0, 5.0, 3.0]))
    rows = np.asarray([3, 2, 1, 0], np.int32)
    perm = np.asarray(async_pop_perm(hi, lo, rows))
    # finish 3.0 pops before 5.0; equal finishes pop in worker order
    np.testing.assert_array_equal(perm, [3, 1, 2, 0])


def test_async_pop_perm_splits_preserve_f64_order():
    # residual-level differences (below f32 resolution) must still order
    t = np.asarray([1.0, 1.0 + 2**-30, 1.0 + 2**-29], np.float64)
    hi, lo = split_time_keys(t)
    assert len(set(hi.tolist())) == 1          # all collide at f32
    perm = np.asarray(async_pop_perm(hi, lo, np.asarray([2, 1, 0], np.int32)))
    np.testing.assert_array_equal(perm, [0, 1, 2])


def test_fused_async_golden_tiebreak():
    """Uniform phi (sigma=1, no jitter): every first-wave finish ties, and
    the plan must pop workers in ascending slot order — the host heap's
    ``(time, worker)`` tuple order — with the fused run reproducing it."""
    W, kw = 8, dict(num_workers=8, sigma=1.0, time_jitter=0.0)
    sim = _cfg("masked", **kw)
    env = _Env(sim)
    plan = _plan_async_events(sim, env, None, np.arange(W))
    assert len(set(plan.finishes[:W].tolist())) == 1   # all-tied first wave
    np.testing.assert_array_equal(
        plan.workers, np.tile(np.arange(W), sim.rounds)
    )
    res = run_simulation(_cfg("masked", async_window=1000.0, **kw))
    fus = run_simulation(_cfg("fused", async_window=1000.0, **kw))
    _assert_async_equivalent(res, fus)


# ---------------------------------------------------------------------------
# host-dispatch + recompile economics
# ---------------------------------------------------------------------------

def test_fused_async_dispatches_scale_with_chunks_not_events():
    res = run_simulation(_cfg("masked"))
    fus = run_simulation(_cfg("fused", round_fusion=4))
    events = 4 * 2                             # n_part * rounds
    # the initial + per-n_part-commits accuracy evals go through the counted
    # jit cache too (2 dispatches each), identically for every engine
    eval_calls = (2 + 1) * 2
    assert fus.fused_chunks == events // 4     # one launch per 4-batch chunk
    assert fus.host_dispatches == fus.fused_chunks + eval_calls
    # resident pays one dispatch per window batch (= per event, serial)
    assert res.host_dispatches == events + eval_calls
    assert fus.host_dispatches < res.host_dispatches
    # one padded chunk signature -> at most the chunk + a tail recompile
    assert fus.recompiles <= 2
    assert fus.compile_walltime_s <= fus.walltime_s


# ---------------------------------------------------------------------------
# dropout under async: golden schedule + churn-only rejection
# ---------------------------------------------------------------------------

def test_async_dropout_golden_schedule():
    """Pinned event stream at seed=5 / scenario seed=3, dropout=0.5: the
    commit order, timed-out commits, staleness integers and version bumps
    are data — any engine or planner change that shifts them fails here."""
    sim = _cfg("masked", scenario=ScenarioConfig(dropout=0.5, seed=3))
    env = _Env(sim)
    scen = ScenarioEngine(sim.scenario, 4)
    plan = _plan_async_events(sim, env, scen, scen.static_participants())
    assert plan.workers.tolist() == [3, 2, 3, 1, 0, 2, 1, 0]
    assert plan.dropped.tolist() == [
        False, True, False, True, False, False, False, False,
    ]
    assert plan.staleness.tolist() == [0, 1, 0, 2, 2, 2, 2, 2]
    # dropped commits never bump the server version
    assert plan.versions.tolist() == [1, 1, 2, 2, 3, 4, 5, 6]
    assert plan.evals.tolist() == [
        False, False, False, True, False, False, False, True,
    ]


def test_async_dropout_discards_payload_but_keeps_quota():
    scen = ScenarioConfig(dropout=0.5, seed=3)
    clean = run_simulation(_cfg("masked"))
    res = run_simulation(_cfg("masked", scenario=scen))
    fus = run_simulation(_cfg("fused", scenario=scen))
    _assert_async_equivalent(res, fus)
    # same commit quota (same number of evals), fewer communicated bytes:
    # 2 of the 8 golden-schedule commits timed out
    assert len(res.acc_time) == len(clean.acc_time)
    assert res.comm_bytes == clean.comm_bytes * (8 - 2) / 8


def test_async_rejects_churn_naming_only_churn():
    with pytest.raises(ValueError, match="churn") as exc:
        run_simulation(_cfg("masked", scenario=ScenarioConfig(churn=0.2)))
    assert "dropout" not in str(exc.value)
    with pytest.raises(ValueError, match="schedule"):
        run_simulation(_cfg("fused", scenario=ScenarioConfig(
            schedule=[ScenarioEngine(ScenarioConfig(), 4).draw(1)]
        )))
