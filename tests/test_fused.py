"""Fused round engine contracts (core.fused).

Pins down:
  * fused == resident == sequential equivalence — final acc within 1e-3,
    identical scenario event streams, and per-round prune indices
    BIT-identical (``SimResult.prune_events``), including under sampling,
    dropout, churn and phase-B training;
  * the device ``prune_presence_rows`` greedy vs the host
    ``prune_to_budget`` — exact retained sets, including score
    tie-breaking and min_units skips;
  * host-dispatch economics: fused runs launch O(rounds / round_fusion)
    jitted programs (``host_dispatches``), the resident engine O(rounds),
    with fused recompiles bounded by the chunk signature count;
  * cross-round resident momentum (opt-in): fused == masked, and both
    differ from the per-phase-reset reference.
"""
import numpy as np
import pytest

from repro.core.masks import (
    flatten_unit_space,
    full_index,
    grow_order,
    index_from_presence,
    presence_from_index,
    prune_budget_units,
    prune_order,
    prune_presence_rows,
    prune_to_budget,
    regrow_index,
    regrow_presence_rows,
)
from repro.core.scenario import ScenarioConfig
from repro.core.simulation import RegrowConfig, SimConfig, run_simulation
from repro.core.timing import HeterogeneityConfig
from repro.models.cnn import build_unit_space, init_cnn, vgg_config

TINY = vgg_config("vgg_tiny_fused", [8, "M", 16], num_classes=4, image_size=8)


def _sim(engine, **kw):
    base = dict(
        method="adaptcl",
        engine=engine,
        rounds=6,
        prune_interval=2,
        num_workers=5,
        batch_size=16,
        cnn=TINY,
        het=HeterogeneityConfig(num_workers=5, sigma=3.0),
        eval_every=2,
        seed=5,
    )
    base.update(kw)
    return run_simulation(SimConfig(**base))


def _assert_equivalent(ref, fused, *, bit_identical_prunes=True):
    assert abs(ref.final_acc - fused.final_acc) <= 1e-3
    assert ref.scenario_rounds == fused.scenario_rounds
    if bit_identical_prunes:
        assert ref.prune_events == fused.prune_events
    # the channel model consumed identical indices + jitter draws
    np.testing.assert_allclose(
        np.array(ref.update_times), np.array(fused.update_times),
        rtol=0, atol=0, equal_nan=True,
    )
    assert ref.total_time == pytest.approx(fused.total_time, abs=1e-9)


# ---------------------------------------------------------------------------
# equivalence: fused == resident == sequential
# ---------------------------------------------------------------------------

def test_fused_matches_sequential_and_resident():
    seq = _sim("sequential")
    res = _sim("masked")
    fus = _sim("fused")
    _assert_equivalent(seq, fus)
    _assert_equivalent(res, fus)
    assert len(fus.prune_events) > 0
    assert fus.host_roundtrips == 0
    assert fus.fused_chunks == 3          # 6 rounds / PI=2 chunks


@pytest.mark.parametrize("scen", [
    ScenarioConfig(participation=0.6, seed=1),
    ScenarioConfig(participation=0.8, dropout=0.2, churn=0.15, seed=2),
])
def test_fused_scenario_streams_identical(scen):
    seq = _sim("sequential", scenario=scen)
    fus = _sim("fused", scenario=scen)
    _assert_equivalent(seq, fus)
    assert len(fus.scenario_rounds) == 6


@pytest.mark.slow
def test_fused_phase_b_and_by_unit():
    for kw in (dict(beta=0.5), dict(aggregation="by_unit")):
        seq = _sim("sequential", **kw)
        fus = _sim("fused", **kw)
        _assert_equivalent(seq, fus)


@pytest.mark.slow
@pytest.mark.parametrize("importance", ["index", "l1", "taylor"])
def test_fused_importance_criteria(importance):
    # l1/taylor are scored ON DEVICE in the fused engine (float32) — the
    # retained sets still match the host float64 path on this fixture
    seq = _sim("sequential", importance=importance)
    fus = _sim("fused", importance=importance)
    _assert_equivalent(seq, fus)


@pytest.mark.slow
def test_fused_round_fusion_cap_spans_learning_intervals():
    # K=2 < PI=3: two chunks per interval, boundaries still at learn events
    seq = _sim("sequential", rounds=7, prune_interval=3)
    fus = _sim("fused", rounds=7, prune_interval=3, round_fusion=2)
    _assert_equivalent(seq, fus)
    assert fus.fused_chunks == 5          # 2+1 | 2+1 | 1


# ---------------------------------------------------------------------------
# device prune_to_budget vs host: exact indices incl. tie-breaking
# ---------------------------------------------------------------------------

def _space():
    import jax

    params = {
        k: np.asarray(v) for k, v in init_cnn(jax.random.PRNGKey(0), TINY).items()
    }
    space, _ = build_unit_space(TINY, params)
    return space


@pytest.mark.parametrize("case", ["random", "ties", "minunits", "zero"])
def test_device_prune_matches_host_golden(case):
    space = _space()
    flat = flatten_unit_space(space)
    rng = np.random.default_rng(3)
    if case == "ties":
        # massive score collisions: the (layer_name, unit) tie-break decides
        scores = {
            l.name: rng.integers(0, 3, l.num_units).astype(np.float64)
            for l in space.layers
        }
        rates = [0.3, 0.55]
    elif case == "minunits":
        # deep cut: min_units guards fire and skipped layers keep budget
        scores = {l.name: rng.normal(size=l.num_units) for l in space.layers}
        rates = [0.97]
    elif case == "zero":
        scores = {l.name: rng.normal(size=l.num_units) for l in space.layers}
        rates = [0.0]
    else:
        scores = {l.name: rng.normal(size=l.num_units) for l in space.layers}
        rates = [0.2, 0.4, 0.7]
    index = full_index(space)
    for rate in rates:
        host = prune_to_budget(index, scores, rate, space)
        order = prune_order(scores, flat)
        budget = prune_budget_units(index, rate, space)
        pres = presence_from_index(index, flat)[None]
        out = np.asarray(prune_presence_rows(
            pres, order[None], np.asarray([budget], np.int32), flat
        ))[0]
        dev = index_from_presence(out, flat)
        for lname in host:
            np.testing.assert_array_equal(
                host[lname], dev[lname],
                err_msg=f"{case} rate={rate} layer={lname}",
            )
        index = host   # chain prunes so nested-index paths are covered too


def test_presence_roundtrip():
    space = _space()
    flat = flatten_unit_space(space)
    rng = np.random.default_rng(0)
    scores = {l.name: rng.normal(size=l.num_units) for l in space.layers}
    idx = prune_to_budget(full_index(space), scores, 0.4, space)
    back = index_from_presence(presence_from_index(idx, flat), flat)
    for lname in idx:
        np.testing.assert_array_equal(idx[lname], back[lname])


# ---------------------------------------------------------------------------
# host-dispatch + recompile economics
# ---------------------------------------------------------------------------

def test_fused_dispatches_scale_with_chunks_not_rounds():
    rounds, fusion = 8, 4
    res = _sim("masked", rounds=rounds, prune_interval=4, eval_every=rounds)
    fus = _sim("fused", rounds=rounds, prune_interval=4, round_fusion=fusion,
               eval_every=rounds)
    # the initial + final accuracy evals go through the counted jit cache
    # too (2 evals x ceil(512 test images / 256) batches) — identical for
    # every engine, so subtract them to see the round-loop dispatches
    eval_calls = 2 * 2
    # fused: one jitted launch per chunk, O(R / round_fusion)
    assert fus.fused_chunks == rounds // fusion
    assert fus.host_dispatches == fus.fused_chunks + eval_calls
    # resident pays at least one dispatch per round (phase A) + prune phases
    assert res.host_dispatches >= rounds + eval_calls
    assert (fus.host_dispatches - eval_calls) * 3 <= (
        res.host_dispatches - eval_calls
    )
    # recompiles bounded by distinct chunk signatures (padding makes it 1),
    # vs the resident engine's (phase shapes x buckets) — never O(rounds)
    assert fus.recompiles <= 2
    assert fus.compile_walltime_s <= fus.walltime_s


def test_fused_zero_host_roundtrips():
    fus = _sim("fused", scenario=ScenarioConfig(participation=0.6, seed=1))
    assert fus.host_roundtrips == 0


# ---------------------------------------------------------------------------
# cross-round resident momentum (opt-in optimizer mode)
# ---------------------------------------------------------------------------

def test_resident_momentum_fused_matches_masked():
    mas = _sim("masked", resident_momentum=True)
    fus = _sim("fused", resident_momentum=True)
    _assert_equivalent(mas, fus)
    drift = max(
        float(np.max(np.abs(mas.global_params[k] - fus.global_params[k])))
        for k in mas.global_params
    )
    assert drift <= 1e-3


def test_resident_momentum_differs_from_reset_and_is_gated():
    reset = _sim("masked")
    mom = _sim("masked", resident_momentum=True)
    drift = max(
        float(np.max(np.abs(reset.global_params[k] - mom.global_params[k])))
        for k in reset.global_params
    )
    assert drift > 1e-6      # the carry actually changes the trajectory
    with pytest.raises(ValueError, match="resident"):
        _sim("sequential", resident_momentum=True)


def test_resident_momentum_under_sampling():
    scen = ScenarioConfig(participation=0.6, seed=3)
    mas = _sim("masked", resident_momentum=True, scenario=scen)
    fus = _sim("fused", resident_momentum=True, scenario=scen)
    _assert_equivalent(mas, fus)


@pytest.mark.slow
def test_resident_momentum_under_churn():
    # churn must zero the replaced slot's velocity in BOTH resident engines
    scen = ScenarioConfig(participation=0.8, dropout=0.1, churn=0.2, seed=4)
    mas = _sim("masked", resident_momentum=True, scenario=scen)
    fus = _sim("fused", resident_momentum=True, scenario=scen)
    _assert_equivalent(mas, fus)


# ---------------------------------------------------------------------------
# device DGC + FedDST mask regrowth
# ---------------------------------------------------------------------------

def test_fused_dgc_matches_resident():
    # device top-|.| keep sets are bit-identical to the host compressor, so
    # clocks, comm bytes AND prune indices line up exactly
    res = _sim("masked", dgc_sparsity=0.5)
    fus = _sim("fused", dgc_sparsity=0.5)
    _assert_equivalent(res, fus)
    assert res.comm_bytes == fus.comm_bytes
    dense = _sim("masked")
    assert fus.comm_bytes < dense.comm_bytes   # compression actually engaged
    assert fus.total_time < dense.total_time   # ... and the channel saw it


def test_fused_regrow_matches_sequential_and_resident():
    # interval=3 does NOT align with prune_interval=2: regrow rounds (4, 7)
    # must cut chunks mid-interval and stay bit-identical anyway
    rg = RegrowConfig(interval=3, alpha0=0.3)
    seq = _sim("sequential", rounds=8, regrow=rg)
    res = _sim("masked", rounds=8, regrow=rg)
    fus = _sim("fused", rounds=8, regrow=rg, round_fusion=4)
    _assert_equivalent(seq, fus)
    _assert_equivalent(res, fus)
    event_rounds = {t for t, _, _ in fus.prune_events}
    assert {4, 7} <= event_rounds              # regrow events recorded
    # regrow adds exactly ONE extra signature (the grow-score gradient)
    assert fus.recompiles <= 2


@pytest.mark.slow
def test_fused_regrow_with_dgc_and_momentum():
    # the full stack at once: readjusted masks + device DGC + resident
    # momentum (regrown units must restart at zero velocity in both engines)
    kw = dict(
        rounds=8, regrow=RegrowConfig(interval=2, alpha0=0.4),
        dgc_sparsity=0.5, resident_momentum=True, round_fusion=4,
    )
    res = _sim("masked", **kw)
    fus = _sim("fused", **kw)
    _assert_equivalent(res, fus)
    assert res.comm_bytes == fus.comm_bytes


def test_regrow_swaps_units_at_near_constant_budget():
    # regrow swaps units, it does not change the budget: the grow greedy
    # restores exactly the removed param mass, within one unit's cost of
    # overshoot (the last grown unit may cross the integer budget)
    space = _space()
    flat = flatten_unit_space(space)
    rng = np.random.default_rng(11)
    scores = {l.name: rng.normal(size=l.num_units) for l in space.layers}
    idx = prune_to_budget(full_index(space), scores, 0.4, space)
    shrink = {l.name: rng.normal(size=l.num_units) for l in space.layers}
    shrunk = prune_to_budget(idx, shrink, 0.3, space)
    budget = sum(
        (len(idx[l.name]) - len(shrunk[l.name])) * l.unit_param_cost
        for l in space.layers
    )
    assert budget > 0
    grow = {l.name: rng.normal(size=l.num_units) for l in space.layers}
    regrown = regrow_index(shrunk, grow, budget, space)

    def mass(i):
        return sum(
            len(i[l.name]) * l.unit_param_cost for l in space.layers
        )

    overshoot = mass(regrown) - mass(idx)
    assert 0 <= overshoot < int(max(flat.costs))
    # ...and it actually SWAPPED units (grow scores != shrink scores)
    assert any(
        set(regrown[l.name]) != set(idx[l.name]) for l in space.layers
    )


def test_regrow_rejected_for_async():
    with pytest.raises(ValueError, match="regrow"):
        _sim("sequential", method="fedasync_s",
             regrow=RegrowConfig(interval=2))


def test_device_regrow_matches_host_golden():
    """masks.regrow_presence_rows replays masks.regrow_index exactly —
    descending-score grow order, integer param budgets, tie-breaking — the
    grow-side mirror of test_device_prune_matches_host_golden."""
    space = _space()
    flat = flatten_unit_space(space)
    rng = np.random.default_rng(7)
    prune_scores = {l.name: rng.normal(size=l.num_units) for l in space.layers}
    idx = prune_to_budget(full_index(space), prune_scores, 0.5, space)
    # integer scores: massive grow-order ties, the (layer, unit) break decides
    grow_scores = {
        l.name: rng.integers(0, 3, l.num_units).astype(np.float64)
        for l in space.layers
    }
    order = grow_order(grow_scores, flat)
    for budget in (0, 3, 17, 10**6):
        host = regrow_index(idx, grow_scores, budget, space)
        pres = presence_from_index(idx, flat)[None]
        out = np.asarray(regrow_presence_rows(
            pres, order[None], np.asarray([budget], np.int32), flat
        ))[0]
        dev = index_from_presence(out, flat)
        for lname in host:
            np.testing.assert_array_equal(
                host[lname], dev[lname], err_msg=f"budget={budget} {lname}"
            )


# ---------------------------------------------------------------------------
# unsupported-config guards
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,frag", [
    # async methods themselves fuse now (tests/test_async_fused.py); the
    # per-commit momentum restart still rejects the resident carry
    (dict(method="fedasync_s", resident_momentum=True), "async"),
    (dict(importance="hrank"), "criteria"),
    (dict(compute="block_skip"), "block_skip"),
])
def test_fused_rejects_unsupported(kw, frag):
    with pytest.raises(ValueError, match=frag):
        _sim("fused", **kw)
