"""Fleet-engine equivalence: bucketed and masked training must reproduce the
sequential `LocalTrainer` reference — per-worker params at the fleet level,
and end-to-end `SimResult` metrics through the simulator."""
import numpy as np
import pytest

from repro.core.fleet import FleetEngine, FleetJob
from repro.core.masks import full_index, prune_to_budget
from repro.core.simulation import SimConfig, run_simulation
from repro.core.timing import HeterogeneityConfig
from repro.core.worker import LocalTrainer, make_batch_plan
from repro.models.cnn import build_unit_space, init_cnn, vgg_config

TINY = vgg_config("vgg_tiny_eqv", [8, "M", 16], num_classes=4, image_size=8)


def _sim(method, engine, **kw):
    base = dict(
        method=method,
        engine=engine,
        rounds=3,
        prune_interval=2,
        num_workers=4,
        cnn=TINY,
        het=HeterogeneityConfig(num_workers=4, sigma=3.0),
        eval_every=1,
        seed=5,
    )
    base.update(kw)
    return run_simulation(SimConfig(**base))


def _fleet_fixture():
    """4 workers: two at full shape, two pruned to different sub-models."""
    import jax

    params = {k: np.asarray(v) for k, v in init_cnn(jax.random.PRNGKey(0), TINY).items()}
    space, unit_map = build_unit_space(TINY, params)
    base_shapes = {k: v.shape for k, v in params.items()}
    rng = np.random.default_rng(0)
    scores = {l.name: rng.normal(size=l.num_units) for l in space.layers}
    full = full_index(space)
    idx_a = prune_to_budget(full, scores, 0.3, space)
    idx_b = prune_to_budget(full, scores, 0.5, space)

    from repro.core.aggregation import extract_subparams

    indices = [full, full, idx_a, idx_b]
    worker_params = [extract_subparams(params, idx, unit_map) for idx in indices]
    xs = [rng.normal(size=(64, 8, 8, 3)).astype(np.float32) for _ in range(4)]
    ys = [rng.integers(0, 4, 64).astype(np.int32) for _ in range(4)]
    return unit_map, base_shapes, indices, worker_params, xs, ys


def _train_all(engine, unit_map, base_shapes, indices, worker_params, xs, ys, lam):
    trainer = LocalTrainer(TINY, lr=0.05)
    fleet = FleetEngine(trainer, unit_map, base_shapes, engine=engine)
    rng = np.random.default_rng(7)  # same plan stream for every engine
    jobs = [
        FleetJob(worker=w, params=worker_params[w], index=indices[w],
                 x=xs[w], y=ys[w], plan=make_batch_plan(64, 16, 1.0, rng))
        for w in range(4)
    ]
    return fleet.train_all(jobs, lam), trainer.compile_count


@pytest.mark.slow
@pytest.mark.parametrize("lam", [0.0, 1e-3, 1e-2])
def test_per_worker_params_match_sequential(lam):
    fixture = _fleet_fixture()
    ref, _ = _train_all("sequential", *fixture, lam)
    for engine in ("bucketed", "masked"):
        out, _ = _train_all(engine, *fixture, lam)
        for w in range(4):
            for k in ref[w]:
                np.testing.assert_allclose(
                    out[w][k], ref[w][k], atol=1e-3,
                    err_msg=f"{engine} worker {w} param {k}",
                )


def test_bucketed_groups_same_shapes_into_one_program():
    fixture = _fleet_fixture()
    _, compiles = _train_all("bucketed", *fixture, 0.0)
    # 4 workers but only 3 distinct shape signatures -> 3 compiled programs
    assert compiles == 3


def test_masked_engine_single_program():
    fixture = _fleet_fixture()
    _, compiles = _train_all("masked", *fixture, 0.0)
    assert compiles == 1


@pytest.mark.slow
@pytest.mark.parametrize("method", ["adaptcl", "fedavg_s"])
def test_sim_results_equivalent_across_engines(method):
    seq = _sim(method, "sequential")
    for engine in ("bucketed", "masked"):
        alt = _sim(method, engine)
        assert alt.final_acc == pytest.approx(seq.final_acc, abs=1e-3)
        assert alt.best_acc == pytest.approx(seq.best_acc, abs=1e-3)
        # virtual time / retention depend on shapes and shared RNG draws only
        assert alt.total_time == pytest.approx(seq.total_time, rel=1e-9)
        assert alt.retentions == pytest.approx(seq.retentions)
        assert alt.engine == engine


@pytest.mark.slow
def test_recompiles_sublinear_in_pruning_events():
    """10 heterogeneous workers, 3 prune events: batched engines must compile
    fewer programs than the workers x prune-events recompile model."""
    rounds, pi, workers = 6, 2, 10
    events = rounds // pi
    kw = dict(rounds=rounds, prune_interval=pi, num_workers=workers,
              het=HeterogeneityConfig(num_workers=workers, sigma=5.0), eval_every=3)
    seq = _sim("adaptcl", "sequential", **kw)
    buck = _sim("adaptcl", "bucketed", **kw)
    mask = _sim("adaptcl", "masked", **kw)
    assert buck.recompiles < workers * events
    assert mask.recompiles < workers * events
    # masked mode never reconfigures: one program for the whole run
    assert mask.recompiles <= 2
    assert mask.batched_calls == rounds
    for alt in (buck, mask):
        assert alt.final_acc == pytest.approx(seq.final_acc, abs=1e-3)
