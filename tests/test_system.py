"""End-to-end behaviour of the collaborative-learning system (paper claims)."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # full simulator runs; quick pass: -m "not slow"

from repro.core.pruned_rate import PrunedRateConfig
from repro.core.simulation import SimConfig, run_simulation
from repro.core.timing import HeterogeneityConfig
from repro.models.cnn import vgg_config

TINY = vgg_config("vgg_tiny_test", [8, "M", 16], num_classes=4, image_size=8)


def _sim(method, **kw):
    base = dict(
        method=method,
        rounds=8,
        prune_interval=2,
        num_workers=4,
        cnn=TINY,
        het=HeterogeneityConfig(num_workers=4, sigma=3.0),
        eval_every=4,
        seed=3,
    )
    base.update(kw)
    return run_simulation(SimConfig(**base))


def test_adaptcl_reduces_heterogeneity_and_time():
    fed = _sim("fedavg_s")
    ada = _sim("adaptcl")
    # dragger removal: virtual wall-clock strictly better
    assert ada.total_time < fed.total_time
    # heterogeneity of update times falls below the starting level
    h_first = ada.het_traj[0][1]
    h_last = np.mean([h for _, h in ada.het_traj[-2:]])
    assert h_last < h_first * 0.6, (h_first, h_last)
    # fastest worker keeps (almost) everything, slower workers pruned
    assert ada.retentions[-1] > max(ada.retentions[0], ada.retentions[1])
    assert ada.param_reduction > 0.05


def test_adaptcl_nested_submodels_final():
    ada = _sim("adaptcl")
    rets = np.array(ada.retentions)
    assert (rets <= 1.0 + 1e-9).all() and (rets > 0.0).all()


def test_async_methods_run_and_report():
    for method in ("fedasync_s", "ssp_s", "dcasgd_s"):
        r = _sim(method, rounds=4)
        assert r.total_time > 0
        assert 0.0 <= r.best_acc <= 1.0
        assert len(r.acc_time) >= 2


def test_by_unit_aggregation_runs():
    r = _sim("adaptcl", aggregation="by_unit")
    assert 0.0 <= r.final_acc <= 1.0


def test_fixed_pruned_rates_table9_mode():
    rates = [[0.5, 0.3, 0.2, 0.0], [0.3, 0.2, 0.2, 0.0]]
    r = _sim("adaptcl", fixed_pruned_rates=rates)
    # worker 3 never pruned; worker 0 pruned twice
    assert r.retentions[3] == pytest.approx(1.0)
    assert r.retentions[0] < 0.6
    assert r.retentions[0] < r.retentions[1] <= 1.0


def test_server_overhead_is_small():
    ada = _sim("adaptcl")
    # Alg.2 + aggregation host time is a negligible fraction of simulated
    # round time budget (paper: "computational overhead ... negligible")
    assert ada.server_overhead_s < 5.0


def test_checkpoint_roundtrip(tmp_path):
    import jax
    from repro.checkpoint.checkpoint import load_checkpoint, save_checkpoint
    from repro.models.cnn import init_cnn

    params = {k: np.asarray(v) for k, v in init_cnn(jax.random.PRNGKey(0), TINY).items()}
    gidx = {"conv0": np.array([0, 2, 5])}
    order = {"conv0": np.array([2.0, 0.5, 1.0])}
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, params, step=7, global_index=gidx, importance_order=order)
    loaded, extras = load_checkpoint(path)
    assert extras["step"] == 7
    assert np.array_equal(extras["global_index"]["conv0"], gidx["conv0"])
    assert np.array_equal(extras["importance_order"]["conv0"], order["conv0"])
    for k in params:
        assert np.allclose(loaded[k], params[k])


def test_adaptcl_plus_dgc_reduces_comm_and_time():
    """Appendix E: DGC compression composes with AdaptCL (orthogonal local
    acceleration) — less communication, faster rounds."""
    r0 = _sim("adaptcl")
    r9 = _sim("adaptcl", dgc_sparsity=0.9)
    assert r9.comm_bytes < r0.comm_bytes * 0.4
    assert r9.total_time < r0.total_time
