"""By-worker vs By-unit aggregation (paper §III-B, Appendix A Fig. 6)."""
import numpy as np

from repro.core.aggregation import (
    aggregate_by_unit,
    aggregate_by_worker,
    coordinate_mask,
    embed_params,
    extract_subparams,
)

# One weight matrix [2 in, 3 out-units]; unit layer "u" governs axis 1.
UNIT_MAP = {"w": [("u", 1)]}
BASE_SHAPES = {"w": (2, 3)}


def _sub(vals, idx):
    return ({"w": np.asarray(vals, np.float64)}, {"u": np.asarray(idx)})


def test_fig6_by_worker_vs_by_unit():
    """3 workers; the first pruned unit 2 (W=3, w'=2 for that column)."""
    s1 = _sub([[1, 1], [1, 1]], [0, 1])          # retains units 0,1
    s2 = _sub([[2, 2, 2], [2, 2, 2]], [0, 1, 2])
    s3 = _sub([[4, 4, 4], [4, 4, 4]], [0, 1, 2])
    bw = aggregate_by_worker([s1, s2, s3], UNIT_MAP, BASE_SHAPES)
    bu = aggregate_by_unit([s1, s2, s3], UNIT_MAP, BASE_SHAPES)
    # by-worker: pruned coordinate counted as 0 -> (0+2+4)/3 = 2
    assert np.allclose(bw["w"][:, 2], 2.0)
    assert np.allclose(bw["w"][:, 0], (1 + 2 + 4) / 3)
    # by-unit: only the 2 holders average -> (2+4)/2 = 3
    assert np.allclose(bu["w"][:, 2], 3.0)
    assert np.allclose(bu["w"][:, 0], (1 + 2 + 4) / 3)


def test_extract_embed_roundtrip():
    rng = np.random.default_rng(0)
    full = {"w": rng.normal(size=(2, 3))}
    idx = {"u": np.array([0, 2])}
    sub = extract_subparams(full, idx, UNIT_MAP)
    assert sub["w"].shape == (2, 2)
    emb = embed_params(sub, idx, UNIT_MAP, BASE_SHAPES)
    assert np.allclose(emb["w"][:, [0, 2]], full["w"][:, [0, 2]])
    assert np.allclose(emb["w"][:, 1], 0.0)


def test_aggregation_fixed_point():
    """All workers submitting the identical full model leaves it unchanged."""
    rng = np.random.default_rng(1)
    full = {"w": rng.normal(size=(2, 3))}
    idx = {"u": np.arange(3)}
    subs = [({"w": full["w"].copy()}, idx) for _ in range(5)]
    for agg in (aggregate_by_worker, aggregate_by_unit):
        out = agg(subs, UNIT_MAP, BASE_SHAPES)
        assert np.allclose(out["w"], full["w"])


def test_data_weighted_by_worker():
    s1 = _sub([[1, 1, 1], [1, 1, 1]], [0, 1, 2])
    s2 = _sub([[3, 3, 3], [3, 3, 3]], [0, 1, 2])
    out = aggregate_by_worker([s1, s2], UNIT_MAP, BASE_SHAPES, data_weights=[3, 1])
    assert np.allclose(out["w"], 1 * 0.75 + 3 * 0.25)


def test_coordinate_mask_two_axis():
    umap = {"w": [("u", 1), ("r", 0)]}
    shapes = {"w": (2, 3)}
    m = coordinate_mask("w", {"u": np.array([0]), "r": np.array([1])}, umap, shapes)
    assert m.sum() == 1.0 and m[1, 0] == 1.0
