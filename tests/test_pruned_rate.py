"""Algorithm 2 (pruned-rate learning) unit tests."""
import numpy as np
import pytest

from repro.core.pruned_rate import (
    PrunedRateConfig,
    WorkerHistory,
    inverse_interpolate_gamma,
    learn_pruned_rates,
    newton_divided_differences,
    newton_eval,
)


def test_newton_interpolation_exact_on_polynomials():
    rng = np.random.default_rng(0)
    for deg in range(1, 6):
        coeffs = rng.normal(size=deg + 1)
        xs = np.linspace(0.5, 3.0, deg + 1)
        ys = np.polyval(coeffs, xs)
        c = newton_divided_differences(xs, ys)
        for x in np.linspace(0.6, 2.9, 7):
            assert abs(newton_eval(c, xs, x) - np.polyval(coeffs, x)) < 1e-8


def test_newton_rejects_duplicate_nodes():
    with pytest.raises(ZeroDivisionError):
        newton_divided_differences([1.0, 1.0], [2.0, 3.0])


def test_inverse_interpolation_linear_channel():
    # phi(gamma) = 2 + 8*gamma  -> gamma(phi) recovered exactly from 2 points
    h = WorkerHistory()
    for g in (1.0, 0.6):
        h.record(g, 2 + 8 * g)
    g = inverse_interpolate_gamma(h, phi_target=2 + 8 * 0.35)
    assert abs(g - 0.35) < 1e-9


def test_history_cap_truncates_by_recency_not_phi():
    """Regression: the Runge guard must keep the most RECENT checkpoints.

    The old code sorted the nodes by ascending phi first and applied the
    ``max_history`` cap afterwards, so the largest-phi nodes — the stale
    early measurements from the unpruned model — survived forever while
    fresh small-phi checkpoints were dropped.  A worker whose channel has
    settled onto a clean linear law must interpolate through its recent
    window only."""
    h = WorkerHistory()
    # stale round-1 outlier: congested channel, wildly off the settled law
    h.record(1.0, 500.0)
    # 8 recent checkpoints on the settled channel phi(gamma) = 2 + 8*gamma
    for g in np.linspace(0.9, 0.2, 8):
        h.record(float(g), 2.0 + 8.0 * float(g))
    g = inverse_interpolate_gamma(h, phi_target=2.0 + 8.0 * 0.35, max_history=8)
    assert abs(g - 0.35) < 1e-6


def test_bootstrap_rate_formula():
    # never-pruned workers use P = (phi - phi_min) / (alpha * phi)
    cfg = PrunedRateConfig(alpha=2.0, rho_min=0.0)
    hists = [WorkerHistory(), WorkerHistory()]
    hists[0].record(1.0, 10.0)
    hists[1].record(1.0, 5.0)
    rates = learn_pruned_rates(hists, [1.0, 1.0], [10.0, 5.0], cfg)
    assert abs(rates[0] - (10 - 5) / (2 * 10)) < 1e-12
    assert rates[1] == 0.0  # fastest worker never prunes


def test_rate_clipping_and_gamma_min():
    cfg = PrunedRateConfig(rho_max=0.5, gamma_min=0.4, alpha=1.0, rho_min=0.0)
    hists = [WorkerHistory()]
    hists[0].record(1.0, 100.0)
    # bootstrap would want (100-1)/100 = 0.99 -> clipped to rho_max, then
    # gamma_min: 1.0*(1-0.5)=0.5 >= 0.4 so rho_max binds
    rates = learn_pruned_rates(hists, [1.0], [100.0], cfg)
    # phi_min is this worker's own time -> 0; use two workers instead
    hists.append(WorkerHistory())
    hists[1].record(1.0, 1.0)
    rates = learn_pruned_rates(hists, [1.0, 1.0], [100.0, 1.0], cfg)
    assert rates[0] == 0.5

    cfg2 = PrunedRateConfig(rho_max=0.95, gamma_min=0.4, alpha=1.0, rho_min=0.0)
    rates = learn_pruned_rates(hists, [1.0, 1.0], [100.0, 1.0], cfg2)
    assert abs(rates[0] - 0.6) < 1e-12  # 1*(1-p) >= 0.4


def test_skip_tiny_prunings():
    cfg = PrunedRateConfig(rho_min=0.05)
    h0, h1 = WorkerHistory(), WorkerHistory()
    # worker 0 has already converged close to the target
    h0.record(1.0, 10.0)
    h0.record(0.52, 5.05)
    h1.record(1.0, 5.0)
    h1.record(1.0, 5.0)
    rates = learn_pruned_rates([h0, h1], [0.52, 1.0], [5.05, 5.0], cfg)
    assert rates[0] == 0.0  # below rho_min -> skipped (Alg.2 line 5-6)


def test_convergence_on_synthetic_channel():
    """Iterating Alg.2 against phi = c_w*gamma + t should equalize times in
    a few prunings (paper Fig. 8/9)."""
    rng = np.random.default_rng(1)
    W = 6
    comm = np.array([9.0, 7.0, 5.0, 3.0, 2.0, 1.0])
    t_train = 1.0
    gammas = np.ones(W)
    hists = [WorkerHistory() for _ in range(W)]
    cfg = PrunedRateConfig(rho_max=0.5, gamma_min=0.05, rho_min=0.01)

    def phi(w, g):
        return comm[w] * g + t_train

    for it in range(6):
        phis = [phi(w, gammas[w]) for w in range(W)]
        for w in range(W):
            hists[w].record(gammas[w], phis[w])
        rates = learn_pruned_rates(hists, gammas, phis, cfg)
        gammas = gammas * (1 - np.array(rates))
    phis = np.array([phi(w, gammas[w]) for w in range(W)])
    spread = phis.max() / phis.min()
    assert spread < 1.15, f"update times did not converge: {phis}"
