"""Data partition (Non-IID, §IV-A) + channel model (Eq. 6/7) tests."""
import numpy as np

from repro.core.timing import (
    HeterogeneityConfig,
    heterogeneity_closed_form,
    heterogeneity_from_times,
    make_bandwidths,
)
from repro.data.synthetic import SyntheticImageTask, batch_iterator, partition_noniid


def test_noniid_partition_equal_sizes_and_coverage():
    y = np.random.default_rng(0).integers(0, 10, 1000)
    for s in (0.0, 50.0, 80.0):
        shards = partition_noniid(y, 10, s, seed=1)
        sizes = [len(sh) for sh in shards]
        assert max(sizes) - min(sizes) <= 10           # equal data per worker
        allidx = np.concatenate(shards)
        assert len(np.unique(allidx)) == len(allidx) == 1000  # exact cover


def test_noniid_skew_increases_with_s():
    """Higher s% -> more label-concentrated workers (paper's Non-IID knob)."""
    y = np.random.default_rng(0).integers(0, 10, 2000)

    def skew(s):
        shards = partition_noniid(y, 10, s, seed=1)
        # mean max-class fraction per worker
        fracs = []
        for sh in shards:
            counts = np.bincount(y[sh], minlength=10)
            fracs.append(counts.max() / counts.sum())
        return float(np.mean(fracs))

    assert skew(0.0) < skew(50.0) < skew(95.0)


def test_batch_iterator_fractional_epochs():
    x = np.arange(100)[:, None].astype(np.float32)
    y = np.arange(100).astype(np.int32)
    rng = np.random.default_rng(0)
    seen = sum(len(xb) for xb, _ in batch_iterator(x, y, 32, 0.5, rng))
    assert 32 <= seen <= 64  # ~half an epoch (DC-ASGD's E=0.5)


def test_synthetic_task_learnable_structure():
    t = SyntheticImageTask(num_classes=4, image_size=8, train_size=200, test_size=50, noise=0.1)
    # with low noise, nearest-prototype classification should beat chance by a lot
    protos = t.prototypes.reshape(4, -1)
    x = t.x_test.reshape(len(t.x_test), -1)
    pred = np.argmin(((x[:, None, :] - protos[None]) ** 2).sum(-1), axis=1)
    assert (pred == t.y_test).mean() > 0.9


def test_eq6_eq7_bandwidths_roundtrip():
    """Bandwidths from Eq. 7 must reproduce the Eq. 6 update-time spread."""
    cfg = HeterogeneityConfig(num_workers=10, sigma=5.0, bandwidth_max=5e6)
    model_bytes, t_train = 2.0e6, 1.0
    bws = make_bandwidths(cfg, model_bytes, t_train)
    phis = [2.0 * model_bytes / b + t_train for b in bws]
    assert abs(max(phis) / min(phis) - 5.0) < 1e-6     # sigma recovered
    assert np.argmin(phis) == len(phis) - 1            # worker W fastest
    diffs = np.diff(sorted(phis))
    assert np.allclose(diffs, diffs[0], rtol=1e-6)     # uniform spread (Eq. 6)


def test_single_worker_fleet_guards():
    """Regression: W=1 used to divide by (W-1) in Eq. 6/8.  A lone worker is
    its own fastest peer — zero heterogeneity, bandwidth exactly B_max."""
    cfg = HeterogeneityConfig(num_workers=1, sigma=2.0, bandwidth_max=5e6)
    bws = make_bandwidths(cfg, 2.0e6, 1.0)
    assert bws == [5e6]
    # auto-scaled B_max path (bandwidth_max=None) must not divide by zero either
    auto = make_bandwidths(HeterogeneityConfig(num_workers=1), 2.0e6, 1.0)
    assert len(auto) == 1 and np.isfinite(auto[0]) and auto[0] > 0
    assert heterogeneity_closed_form(1, sigma=2.0) == 0.0
    assert heterogeneity_from_times([3.7]) == 0.0


def test_single_worker_simulation_smoke():
    """A W=1 fleet runs end to end (it used to crash in make_bandwidths)."""
    from repro.core.simulation import SimConfig, run_simulation
    from repro.models.cnn import vgg_config

    tiny = vgg_config("vgg_tiny_w1", [8, "M", 16], num_classes=4, image_size=8)
    res = run_simulation(SimConfig(
        cnn=tiny, method="adaptcl", rounds=4, prune_interval=2,
        num_workers=1, batch_size=16, eval_every=2, seed=3,
        het=HeterogeneityConfig(num_workers=1),
    ))
    assert res.final_acc > 0.3
    assert all(h == 0.0 for _, h in res.het_traj)
    assert res.retentions == [1.0]      # its own fastest peer: never prunes
