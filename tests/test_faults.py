"""Fault-injection world model contracts (core.faults).

Pins down:
  * fault-free bit-identity — ``faults=None`` and an all-inactive
    ``FaultConfig()`` produce byte-identical runs (prune events, update
    times, virtual clocks, accuracy) because the fault overlay consumes
    ZERO draws from any RNG stream when off;
  * every fault family unfolds identically under sequential, masked and
    fused engines: same ledgers, bit-identical clocks and prune indices,
    accuracy within 1e-3;
  * graceful degradation — a regional outage that starves
    ``min_participants`` skips rounds (virtual clock advances, global
    untouched, no hang, no exception) and survivors above the floor
    aggregate a partial cohort;
  * capability drift triggers Alg. 2 re-learning within one round of the
    jump, through the bootstrap path (history invalidated);
  * crash/recovery — returning workers re-enter with their last mask but
    restart momentum/DGC residuals, and sit out ``recovery_rounds`` before
    counting toward aggregation;
  * async schedulers support crash/recovery and reject outage/drift/wave
    by field name;
  * fused dispatch economics hold under faults: chunks cut only at
    drift boundaries (crash/outage/wave ride in-scan), recompiles <= 2.
"""
import numpy as np
import pytest

from repro.core.faults import (
    CrashConfig,
    DriftConfig,
    FaultConfig,
    OutageConfig,
    WaveConfig,
    fault_ledger,
)
from repro.core.scenario import ScenarioConfig, ScenarioEngine
from repro.core.simulation import SimConfig, run_simulation
from repro.core.timing import HeterogeneityConfig, drift_multiplier
from repro.models.cnn import vgg_config

TINY = vgg_config("vgg_tiny_flt", [8, "M", 16], num_classes=4, image_size=8)

LEDGER_FIELDS = (
    "drift_events", "rounds_degraded", "rounds_skipped",
    "workers_recovered", "retry_total",
    "byz_commits", "lost_commits", "dup_commits", "corrupt_commits",
)

DRIFT = FaultConfig(drift=DriftConfig(worker=1, round=3, factor=3.0))
CRASH = FaultConfig(crash=CrashConfig(rate=0.25, outage_rounds=2,
                                      recovery_rounds=1))
OUTAGE = FaultConfig(outage=OutageConfig(start=3, length=2,
                                         slot_lo=0, slot_hi=3))
WAVE = FaultConfig(wave=WaveConfig(amplitude=0.6, period=4))
COMBINED = FaultConfig(
    drift=DriftConfig(worker=0, round=3, factor=2.0, mode="ramp",
                      ramp_rounds=3),
    crash=CrashConfig(rate=0.15),
    outage=OutageConfig(start=5, length=2, slot_lo=2, slot_hi=5),
    wave=WaveConfig(amplitude=0.4, period=5),
)


def _sim(engine, **kw):
    base = dict(
        method="adaptcl",
        engine=engine,
        rounds=8,
        prune_interval=2,
        num_workers=5,
        batch_size=16,
        cnn=TINY,
        het=HeterogeneityConfig(num_workers=5, sigma=3.0),
        eval_every=2,
        seed=5,
    )
    base.update(kw)
    return run_simulation(SimConfig(**base))


def _ledger(r):
    return {f: getattr(r, f) for f in LEDGER_FIELDS}


def _assert_engines_match(ref, other):
    assert abs(ref.final_acc - other.final_acc) <= 1e-3
    assert ref.prune_events == other.prune_events
    assert ref.scenario_rounds == other.scenario_rounds
    np.testing.assert_allclose(
        np.array(ref.update_times), np.array(other.update_times),
        rtol=0, atol=0, equal_nan=True,
    )
    assert ref.total_time == pytest.approx(other.total_time, abs=1e-9)
    assert _ledger(ref) == _ledger(other)


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

def test_fault_config_validation():
    with pytest.raises(ValueError):
        DriftConfig(factor=0.0)
    with pytest.raises(ValueError):
        DriftConfig(mode="teleport")
    with pytest.raises(ValueError):
        DriftConfig(mode="ramp", ramp_rounds=0)
    with pytest.raises(ValueError):
        CrashConfig(rate=1.0)
    with pytest.raises(ValueError):
        CrashConfig(outage_rounds=0)
    with pytest.raises(ValueError):
        CrashConfig(recovery_rounds=-1)
    with pytest.raises(ValueError):
        OutageConfig(start=1, length=0, slot_lo=0, slot_hi=1)
    with pytest.raises(ValueError):
        OutageConfig(start=1, length=1, slot_lo=2, slot_hi=2)
    with pytest.raises(ValueError):
        WaveConfig(amplitude=1.0)
    with pytest.raises(ValueError):
        WaveConfig(period=1)
    # engine-level: fault targets must fit the worker pool
    with pytest.raises(ValueError, match="drift worker"):
        ScenarioEngine(ScenarioConfig(
            faults=FaultConfig(drift=DriftConfig(worker=7))), 4)
    with pytest.raises(ValueError, match="outage slots"):
        ScenarioEngine(ScenarioConfig(
            faults=FaultConfig(outage=OutageConfig(
                start=1, length=1, slot_lo=0, slot_hi=9))), 4)
    assert not FaultConfig().any_active
    assert FaultConfig(wave=WaveConfig()).any_active


def test_drift_multiplier_jump_and_ramp():
    assert drift_multiplier(2, 3, 4.0) == 1.0
    assert drift_multiplier(3, 3, 4.0) == 4.0
    assert drift_multiplier(9, 3, 4.0) == 4.0
    # ramp: linear from start_round to start_round + ramp_rounds - 1
    ramp = [drift_multiplier(t, 3, 4.0, ramp_rounds=3) for t in (2, 3, 4, 5, 6)]
    assert ramp == [1.0, 2.0, 3.0, 4.0, 4.0]
    d = DriftConfig(worker=0, round=3, factor=4.0, mode="ramp", ramp_rounds=3)
    assert [d.mult_at(t) for t in (2, 3, 4, 5)] == [1.0, 2.0, 3.0, 4.0]
    j = DriftConfig(worker=0, round=3, factor=4.0, mode="jump", ramp_rounds=9)
    assert j.mult_at(3) == 4.0                  # jump ignores ramp_rounds


def test_outage_for_shard_aligns_with_mesh_layout():
    # shard s of a W=8 fleet over 4 shards owns slots [2s, 2s+2)
    o = OutageConfig.for_shard(start=2, length=3, shard=1,
                               num_workers=8, num_shards=4)
    assert (o.slot_lo, o.slot_hi) == (2, 4)
    assert not o.covers(1) and o.covers(2) and o.covers(4) and not o.covers(5)


# ---------------------------------------------------------------------------
# fault-free bit-identity: the overlay is invisible when off
# ---------------------------------------------------------------------------

def test_inactive_faultconfig_is_bit_identical_to_none():
    base = dict(participation=0.8, dropout=0.2, seed=2)
    a = _sim("sequential", scenario=ScenarioConfig(**base))
    b = _sim("sequential", scenario=ScenarioConfig(faults=FaultConfig(), **base))
    assert a.final_acc == b.final_acc
    assert a.total_time == b.total_time
    assert a.prune_events == b.prune_events
    np.testing.assert_array_equal(
        np.array(a.update_times), np.array(b.update_times)
    )
    assert _ledger(b) == {f: 0 for f in LEDGER_FIELDS}


def test_fault_stream_leaves_base_draws_untouched():
    """Enabling faults must not perturb the sampling/dropout/churn stream:
    crash draws come from a dedicated fault RNG, and drift/outage/wave are
    deterministic — so the BASE masks match the fault-free run draw for
    draw (the overlay only intersects them with the offline set)."""
    cfg = dict(participation=0.8, dropout=0.3, churn=0.1, seed=7)
    plain = ScenarioEngine(ScenarioConfig(**cfg), 6)
    faulty = ScenarioEngine(ScenarioConfig(
        faults=FaultConfig(crash=CrashConfig(rate=0.4, outage_rounds=1)),
        **cfg), 6)
    for t in range(1, 12):
        ep, ef = plain.draw(t), faulty.draw(t)
        on = ~ef.offline
        np.testing.assert_array_equal(ep.active & on, ef.active)
        np.testing.assert_array_equal(ep.joined & on, ef.joined)
        assert not (ef.active & ef.offline).any()


# ---------------------------------------------------------------------------
# engine equivalence under every fault family
# ---------------------------------------------------------------------------

FAMILIES = {
    "drift": dict(seed=3, faults=DRIFT),
    "crash": dict(seed=3, faults=CRASH),
    "outage": dict(seed=3, min_participants=4, faults=OUTAGE),
    "wave": dict(seed=3, participation=0.7, faults=WAVE),
    "combined": dict(seed=3, min_participants=4, participation=0.9,
                     faults=COMBINED),
}


@pytest.mark.parametrize("family", ["drift", "outage"])
def test_fault_families_engine_equivalent_quick(family):
    scen = ScenarioConfig(**FAMILIES[family])
    seq = _sim("sequential", scenario=scen)
    fus = _sim("fused", scenario=scen)
    _assert_engines_match(seq, fus)


@pytest.mark.slow
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_fault_families_engine_equivalent(family):
    scen = ScenarioConfig(**FAMILIES[family])
    seq = _sim("sequential", scenario=scen)
    res = _sim("masked", scenario=scen)
    fus = _sim("fused", scenario=scen)
    _assert_engines_match(seq, res)
    _assert_engines_match(seq, fus)


# ---------------------------------------------------------------------------
# goldens: drift re-learning, degradation floor, crash/recovery
# ---------------------------------------------------------------------------

def test_drift_triggers_relearning_within_one_interval():
    """Worker 1 slows 3x at round 3 — MID-interval under PI=2, where the
    regular cadence learns at rounds 2/4/6 (pruning 3/5/7).  The drift
    trigger re-runs Alg. 2 AT round 3 with worker 1's history invalidated,
    so the drift run prunes worker 1 at round 4 — a round where the
    fault-free run never prunes anyone."""
    r = _sim("fused", scenario=ScenarioConfig(seed=3, faults=DRIFT))
    assert r.drift_events == 1
    assert r.rounds_skipped == 0
    assert any(rnd == 4 and w == 1 for rnd, w, _ in r.prune_events), \
        r.prune_events
    no_fault = _sim("fused", scenario=ScenarioConfig(seed=3))
    assert not any(rnd == 4 for rnd, _, _ in no_fault.prune_events), \
        no_fault.prune_events


def test_outage_below_floor_skips_and_advances():
    """Slots 0-2 go dark for rounds 3-4 with min_participants=4: the two
    rounds are skipped (global untouched, NaN update-time rows), the
    virtual clock still advances through them, and the run completes."""
    scen = ScenarioConfig(seed=3, min_participants=4, faults=OUTAGE)
    r = _sim("sequential", scenario=scen)
    assert r.rounds_skipped == 2
    assert r.rounds_degraded == 0            # below-floor rounds never aggregate
    ut = np.array(r.update_times)
    assert np.isnan(ut[2]).all() and np.isnan(ut[3]).all()
    assert not np.isnan(ut[4]).all()         # survivors resume after the window
    # the skipped rounds still cost wall-clock: strictly fewer aggregations
    # but a clock that moved past the straggler deadline both times
    assert r.total_time > 0.0
    assert r.workers_recovered == 3          # the dark region returns at once
    assert len(r.scenario_rounds) == 8       # no round vanished from the log


def test_outage_above_floor_degrades_gracefully():
    """Same outage with min_participants=1: survivors aggregate a partial
    cohort — rounds are degraded, not skipped."""
    scen = ScenarioConfig(seed=3, min_participants=1, faults=OUTAGE)
    r = _sim("sequential", scenario=scen)
    assert r.rounds_skipped == 0
    assert r.rounds_degraded >= 2
    ut = np.array(r.update_times)
    # dark slots show no update time; survivors do
    assert np.isnan(ut[2, :3]).all() and np.isfinite(ut[2, 3:]).any()


def test_crash_recovery_ledger_and_reentry():
    r = _sim("sequential", scenario=ScenarioConfig(seed=3, faults=CRASH))
    assert r.workers_recovered > 0
    # every recovered worker sits out recovery_rounds=1 before aggregating
    assert r.retry_total == r.workers_recovered
    assert r.rounds_degraded > 0
    assert r.rounds_skipped == 0             # min_participants=1 never starves


def test_fault_ledger_pure_function():
    eng = ScenarioEngine(ScenarioConfig(seed=3, min_participants=4,
                                        faults=OUTAGE), 5)
    events = [eng.draw(t) for t in range(1, 9)]
    led = fault_ledger(events)
    assert led["rounds_skipped"] == 2
    assert led["workers_recovered"] == 3
    # plain pre-feature events (no fault fields) ledger to all-zero
    from repro.core.scenario import full_participation
    assert fault_ledger([full_participation(4)]) == {
        f: 0 for f in LEDGER_FIELDS
    }


# ---------------------------------------------------------------------------
# fused dispatch economics under faults
# ---------------------------------------------------------------------------

def test_fused_chunks_cut_only_at_drift_boundaries():
    # crash faults ride in-scan: same chunk count as the fault-free run
    free = _sim("fused", scenario=ScenarioConfig(seed=3))
    crash = _sim("fused", scenario=ScenarioConfig(seed=3, faults=CRASH))
    assert crash.fused_chunks == free.fused_chunks
    assert crash.recompiles <= 2
    # a single jump adds at most one extra boundary
    drift = _sim("fused", scenario=ScenarioConfig(seed=3, faults=DRIFT))
    assert drift.fused_chunks <= free.fused_chunks + 1
    assert drift.recompiles <= 2


# ---------------------------------------------------------------------------
# async: crash supported, outage/drift/wave rejected by name
# ---------------------------------------------------------------------------

def _async(engine, scen, method="fedasync_s"):
    return run_simulation(SimConfig(
        method=method, engine=engine, rounds=3, num_workers=5,
        batch_size=16, cnn=TINY,
        het=HeterogeneityConfig(num_workers=5, sigma=3.0),
        eval_every=2, seed=5, scenario=scen,
    ))


def test_async_crash_engine_equivalent():
    scen = ScenarioConfig(seed=3, faults=FaultConfig(
        crash=CrashConfig(rate=0.3, outage_rounds=2)))
    res = _async("masked", scen)
    fus = _async("fused", scen)
    assert res.total_time == fus.total_time
    assert [t for t, _ in res.acc_time] == [t for t, _ in fus.acc_time]
    assert _ledger(res) == _ledger(fus)
    assert res.workers_recovered > 0
    for k in res.global_params:
        np.testing.assert_allclose(
            np.asarray(res.global_params[k], np.float32),
            np.asarray(fus.global_params[k], np.float32),
            atol=1e-3, rtol=1e-5, err_msg=k,
        )
    # a crash delays the worker's next commit, so the run's virtual clock
    # stretches past the crash-free one
    free = _async("masked", ScenarioConfig(seed=3))
    assert res.total_time > free.total_time


def test_async_faultfree_bit_identical():
    a = _async("masked", ScenarioConfig(seed=3))
    b = _async("masked", ScenarioConfig(seed=3, faults=FaultConfig()))
    assert a.final_acc == b.final_acc and a.total_time == b.total_time
    assert _ledger(b) == {f: 0 for f in LEDGER_FIELDS}


@pytest.mark.parametrize("method", ["fedasync_s", "ssp_s", "dcasgd_s"])
def test_async_rejects_sync_only_families_by_name(method):
    with pytest.raises(ValueError, match="outage") as exc:
        _async("masked", ScenarioConfig(faults=OUTAGE), method=method)
    assert "drift" not in str(exc.value) and "wave" not in str(exc.value)
    with pytest.raises(ValueError, match="drift") as exc:
        _async("masked", ScenarioConfig(faults=DRIFT), method=method)
    assert "outage" not in str(exc.value) and "wave" not in str(exc.value)
    with pytest.raises(ValueError, match="wave") as exc:
        _async("masked", ScenarioConfig(faults=WAVE), method=method)
    assert "outage" not in str(exc.value) and "drift" not in str(exc.value)


# ---------------------------------------------------------------------------
# mesh-sharded fleet: the same fault world on 1/2/4 devices
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("n_dev", [1, 2, 4])
def test_fault_world_identical_on_mesh(n_dev, eight_devices):
    from repro.launch.mesh import make_fleet_mesh

    scen = ScenarioConfig(seed=3, min_participants=3, faults=FaultConfig(
        drift=DriftConfig(worker=0, round=3, factor=2.0, mode="ramp",
                          ramp_rounds=3),
        crash=CrashConfig(rate=0.15),
        outage=OutageConfig(start=5, length=2, slot_lo=2, slot_hi=4),
        wave=WaveConfig(amplitude=0.4, period=5),
    ))
    seq = _sim("sequential", scenario=scen, num_workers=4,
               het=HeterogeneityConfig(num_workers=4, sigma=3.0))
    shd = _sim("fused", scenario=scen, num_workers=4,
               het=HeterogeneityConfig(num_workers=4, sigma=3.0),
               mesh=make_fleet_mesh(n_dev))
    _assert_engines_match(seq, shd)


@pytest.mark.slow
def test_shard_aligned_outage_on_mesh(eight_devices):
    """OutageConfig.for_shard blacks out exactly one mesh shard's slots;
    the surviving shards aggregate and the run matches the host engine."""
    from repro.launch.mesh import make_fleet_mesh

    out = OutageConfig.for_shard(start=3, length=2, shard=0,
                                 num_workers=4, num_shards=2)
    scen = ScenarioConfig(seed=3, faults=FaultConfig(outage=out))
    seq = _sim("sequential", scenario=scen, num_workers=4,
               het=HeterogeneityConfig(num_workers=4, sigma=3.0))
    shd = _sim("fused", scenario=scen, num_workers=4,
               het=HeterogeneityConfig(num_workers=4, sigma=3.0),
               mesh=make_fleet_mesh(2))
    _assert_engines_match(seq, shd)
    assert seq.rounds_degraded >= 2
