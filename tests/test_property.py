"""Hypothesis property tests on system invariants.

``hypothesis`` is an *optional* test dependency (see tests/requirements-test.txt);
the module skips cleanly when it is not installed.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.fleet import bucket_rows, gather_stack_rows, scatter_stack_rows
from repro.core.importance import METHODS, ImportanceContext
from repro.core.masks import (
    UnitLayer,
    UnitSpace,
    embed_units,
    full_index,
    is_nested,
    prune_to_budget,
    retention,
    similarity,
    take_units,
)
from repro.core.pruned_rate import (
    PrunedRateConfig,
    WorkerHistory,
    learn_pruned_rates,
    newton_divided_differences,
    newton_eval,
)
from repro.core.scenario import ScenarioConfig, ScenarioEngine
from repro.core.timing import heterogeneity_closed_form, heterogeneity_from_times

SPACE = UnitSpace(
    layers=(UnitLayer("a", 24, 10), UnitLayer("b", 40, 7)), fixed_params=300
)


@settings(max_examples=40, deadline=None)
@given(
    coeffs=st.lists(st.floats(-3, 3), min_size=1, max_size=5),
    x=st.floats(0.1, 2.0),
)
def test_newton_reconstructs_polynomials(coeffs, x):
    xs = np.linspace(0.5, 1.5, len(coeffs))
    ys = np.polyval(coeffs, xs)
    c = newton_divided_differences(xs, ys)
    assert abs(newton_eval(c, xs, x) - np.polyval(coeffs, x)) < 1e-6 * (1 + abs(np.polyval(coeffs, x)))


@settings(max_examples=30, deadline=None)
@given(
    rates=st.lists(st.floats(0.0, 0.6), min_size=1, max_size=5),
    rates2=st.lists(st.floats(0.0, 0.6), min_size=1, max_size=5),
    method=st.sampled_from(["cig_bnscalor", "index", "no_adjacent"]),
    seed=st.integers(0, 5),
)
def test_cig_nesting_invariant(rates, rates2, method, seed):
    """ANY two pruning-rate trajectories under a CIG criterion nest."""
    rng = np.random.default_rng(seed)
    scales = {k: rng.random(n) for k, n in SPACE.unit_counts.items()}

    def run(rate_seq, worker):
        idx = full_index(SPACE)
        for rnd, r in enumerate(rate_seq):
            ctx = ImportanceContext(unit_counts=SPACE.unit_counts, scales=scales,
                                    worker=worker, round=rnd, seed=seed)
            idx = prune_to_budget(idx, METHODS[method](ctx), r, SPACE)
        return idx

    ia, ib = run(rates, 0), run(rates2, 1)
    small, big = sorted([ia, ib], key=lambda i: retention(i, SPACE))
    assert is_nested(small, big)
    assert 0.0 <= similarity(ia, ib) <= 1.0


@settings(max_examples=30, deadline=None)
@given(
    phis=st.lists(st.floats(0.5, 50.0), min_size=2, max_size=10),
)
def test_learned_rates_bounded(phis):
    cfg = PrunedRateConfig()
    hists = []
    for p in phis:
        h = WorkerHistory()
        h.record(1.0, p)
        hists.append(h)
    rates = learn_pruned_rates(hists, [1.0] * len(phis), phis, cfg)
    assert all(0.0 <= r <= cfg.rho_max for r in rates)
    assert rates[int(np.argmin(phis))] == 0.0


@settings(max_examples=30, deadline=None)
@given(phis=st.lists(st.floats(0.1, 100.0), min_size=2, max_size=12))
def test_heterogeneity_bounds(phis):
    h = heterogeneity_from_times(phis)
    assert 0.0 - 1e-12 <= h < 1.0
    if max(phis) / min(phis) < 1.0 + 1e-9:
        assert abs(h) < 1e-6


@settings(max_examples=20, deadline=None)
@given(sigma=st.floats(1.0, 30.0), w=st.integers(2, 20))
def test_heterogeneity_closed_form_matches_eq6_times(sigma, w):
    phis = [1.0 * (1.0 + (sigma - 1.0) / (w - 1) * (w - i)) for i in range(1, w + 1)]
    assert abs(heterogeneity_from_times(phis) - heterogeneity_closed_form(w, sigma)) < 1e-9


@settings(max_examples=30, deadline=None)
@given(
    C=st.floats(0.05, 1.0),
    dropout=st.floats(0.0, 0.95),
    churn=st.floats(0.0, 0.8),
    W=st.integers(2, 40),
    seed=st.integers(0, 12),
)
def test_scenario_draw_always_has_a_submitter(C, dropout, churn, W, seed):
    """For EVERY (C, dropout, churn) draw the straggler timeout leaves at
    least one submitter, dropouts are a subset of the sampled cohort, and
    the sampled count respects the floor."""
    cfg = ScenarioConfig(participation=C, dropout=dropout, churn=churn, seed=seed)
    eng = ScenarioEngine(cfg, W)
    for t in range(1, 9):
        ev = eng.draw(t)
        assert ev.submitters.any()
        assert not (ev.dropped & ~ev.active).any()
        assert ev.active.sum() >= cfg.min_participants


@settings(max_examples=30, deadline=None)
@given(
    C=st.floats(0.05, 1.0),
    dropout=st.floats(0.0, 0.95),
    churn=st.floats(0.0, 0.8),
    W=st.integers(2, 24),
    seed=st.integers(0, 12),
)
def test_scenario_stream_identical_across_engines(C, dropout, churn, W, seed):
    """Participation masks are identical under every fleet engine: each
    engine builds its ScenarioEngine from the same config, and the stream
    (round draws AND the async static-participant draw) is a pure function
    of (config, W) on a dedicated RNG — nothing engine-dependent feeds it."""
    cfg = ScenarioConfig(participation=C, dropout=dropout, churn=churn, seed=seed)
    a, b = ScenarioEngine(cfg, W), ScenarioEngine(cfg, W)
    for t in range(1, 7):
        ea, eb = a.draw(t), b.draw(t)
        assert np.array_equal(ea.active, eb.active)
        assert np.array_equal(ea.dropped, eb.dropped)
        assert np.array_equal(ea.joined, eb.joined)
    a2, b2 = ScenarioEngine(cfg, W), ScenarioEngine(cfg, W)
    assert np.array_equal(a2.static_participants(), b2.static_participants())


@settings(max_examples=25, deadline=None)
@given(
    crash_rate=st.floats(0.0, 0.8),
    out_start=st.integers(1, 6),
    out_len=st.integers(1, 3),
    drift_round=st.integers(1, 8),
    factor=st.floats(0.25, 5.0),
    W=st.integers(2, 16),
    minp=st.integers(1, 4),
    seed=st.integers(0, 12),
)
def test_fault_stream_engine_independent(crash_rate, out_start, out_len,
                                         drift_round, factor, W, minp, seed):
    """The scripted fault world is a pure function of (config, W): two
    independent engines replay the identical fault stream draw for draw —
    which is why sequential/masked/fused (and any mesh) see the same
    faults.  Invariants: offline workers never train or submit, a skipped
    round has fewer submitters than the floor, recovered rounds follow
    offline rounds, and the ledger is reproducible."""
    from repro.core.faults import (
        CrashConfig, DriftConfig, FaultConfig, OutageConfig, fault_ledger,
    )

    cfg = ScenarioConfig(
        dropout=0.2, min_participants=min(minp, W), seed=seed,
        faults=FaultConfig(
            drift=DriftConfig(worker=W - 1, round=drift_round, factor=factor),
            crash=CrashConfig(rate=crash_rate, outage_rounds=2,
                              recovery_rounds=1),
            outage=OutageConfig(start=out_start, length=out_len,
                                slot_lo=0, slot_hi=max(1, W // 2)),
        ),
    )
    a, b = ScenarioEngine(cfg, W), ScenarioEngine(cfg, W)
    ea_all, eb_all, prev_off = [], [], np.zeros(W, bool)
    for t in range(1, 10):
        ea, eb = a.draw(t), b.draw(t)
        eb_all.append(eb)
        for f in ("active", "dropped", "joined", "offline", "recovered",
                  "recovering"):
            np.testing.assert_array_equal(getattr(ea, f), getattr(eb, f))
        assert (ea.skip, ea.degraded, ea.drift_changed) == \
            (eb.skip, eb.degraded, eb.drift_changed)
        assert not (ea.active & ea.offline).any()
        assert not (ea.submitters & ea.offline).any()
        if ea.skip:
            assert int(ea.submitters.sum()) < cfg.min_participants
        assert not (ea.recovered & ~prev_off).any()
        prev_off = ea.offline.copy()
        ea_all.append(ea)
    assert fault_ledger(ea_all) == fault_ledger(eb_all)
    drift = cfg.faults.drift
    assert a.drift_mults(drift_round)[drift.worker] == pytest.approx(factor)
    assert a.drift_mults(max(1, drift_round - 1))[0] == 1.0


@settings(max_examples=40, deadline=None)
@given(
    W=st.integers(1, 12),
    nsel=st.integers(1, 12),
    seed=st.integers(0, 20),
)
def test_substack_gather_scatter_roundtrip(W, nsel, seed):
    """The participation sub-stack path is lossless: scatter(gather(rows))
    restores the stacks exactly, trained rows land only on their slots, and
    bucket-padding rows (repeats of row 0) never leak back."""
    import jax.numpy as jnp

    nsel = min(nsel, W)
    rng = np.random.default_rng(seed)
    stacks = {
        "a": jnp.asarray(rng.normal(size=(W, 3, 2)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(W, 5)).astype(np.float32)),
    }
    rows = np.sort(rng.choice(W, size=nsel, replace=False))
    bucket = bucket_rows(nsel, W)
    rows_pad = np.concatenate([rows, np.full(bucket - nsel, rows[0], np.int64)])
    sub = gather_stack_rows(stacks, rows_pad)
    for k in stacks:
        assert sub[k].shape == (bucket,) + stacks[k].shape[1:]
        np.testing.assert_array_equal(np.asarray(sub[k][:nsel]),
                                      np.asarray(stacks[k])[rows])
    # identity round-trip
    same = scatter_stack_rows(stacks, rows, sub)
    for k in stacks:
        np.testing.assert_array_equal(np.asarray(same[k]), np.asarray(stacks[k]))
    # a "trained" sub-stack (padding rows poisoned) lands only on its rows
    shifted = {k: v + 1.0 for k, v in sub.items()}
    out = scatter_stack_rows(stacks, rows, shifted)
    others = np.setdiff1d(np.arange(W), rows)
    for k in stacks:
        np.testing.assert_allclose(np.asarray(out[k])[rows],
                                   np.asarray(stacks[k])[rows] + 1.0, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(out[k])[others],
                                      np.asarray(stacks[k])[others])


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 20),
    keep=st.integers(1, 19),
    axis=st.integers(0, 1),
    seed=st.integers(0, 10),
)
def test_take_embed_adjoint(n, keep, axis, seed):
    keep = min(keep, n)
    rng = np.random.default_rng(seed)
    arr = rng.normal(size=(n, n))
    idx = np.sort(rng.choice(n, size=keep, replace=False))
    sub = take_units(arr, idx, axis)
    emb = embed_units(sub, idx, axis, n)
    assert np.allclose(take_units(emb, idx, axis), sub)
    other = np.setdiff1d(np.arange(n), idx)
    assert np.allclose(take_units(emb, other, axis), 0.0)
