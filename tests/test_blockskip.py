"""Mask-aware block-skip compute path: kernel VJP, model lowering, fleet
equivalence, and the FLOPs-track-retention ledger.

Everything runs ``interpret=True`` on CPU (the kernels' off-TPU fallback), so
the whole file is CI-runnable; on a TPU backend the same code compiles to
Mosaic.  The contracts pinned here:

* the ``pruned_matmul`` custom VJP matches the dense masked reference within
  1e-4 and produces *exactly* zero gradients on pruned in/out units (the
  resident fleet invariant: pruned coordinates stay exactly 0);
* ``cnn_apply(compute="block_skip")`` == the dense path on masked params, for
  VGG and ResNet wiring, forward and backward, including under ``vmap`` with
  per-row masks (one fleet program, heterogeneous retentions);
* a resident ``block_skip`` simulation is numerically equivalent to the
  dense masked engine (final-acc within 1e-3) while its executed-FLOPs
  ledger stays within 1.1x the ideal reconfigured cost at retention 0.25 and
  executes < 0.5x the blocks of retention 1.0.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.simulation import SimConfig, run_simulation
from repro.data.synthetic import SyntheticImageTask
from repro.kernels.pruned_matmul import pruned_matmul
from repro.models.cnn import (
    cnn_apply,
    cnn_block_compute,
    init_cnn,
    prunable_layer_names,
    resnet_config,
    vgg_config,
)

def _masks(rng, K, N, keep=0.5):
    im = (rng.random(K) < keep).astype(np.float32)
    om = (rng.random(N) < keep).astype(np.float32)
    im[0] = om[0] = 1.0  # never fully empty
    return jnp.asarray(im), jnp.asarray(om)


# ---------------------------------------------------------------------------
# kernel-level VJP
# ---------------------------------------------------------------------------

@pytest.mark.parametrize(
    "M,K,N,blocks",
    [
        (128, 256, 128, (128, 128, 128)),   # aligned
        (200, 300, 130, (128, 128, 128)),   # ragged (padded internally)
        (96, 144, 80, (32, 16, 16)),        # small tiles
    ],
)
def test_vjp_matches_dense_reference(M, K, N, blocks):
    rng = np.random.default_rng(M + K + N)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)) * 0.05, jnp.float32)
    im, om = _masks(rng, K, N)
    bm, bn, bk = blocks

    def f(x_, w_):
        y = pruned_matmul(x_, w_, im, om, block_m=bm, block_n=bn, block_k=bk,
                          interpret=True)
        return jnp.sum(jnp.sin(y))

    def f_ref(x_, w_):
        return jnp.sum(jnp.sin((x_ * im[None, :]) @ w_ * om[None, :]))

    np.testing.assert_allclose(float(f(x, w)), float(f_ref(x, w)), rtol=1e-5)
    gx, gw = jax.grad(f, (0, 1))(x, w)
    rx, rw = jax.grad(f_ref, (0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), atol=1e-4, rtol=1e-4)
    # pruned units get EXACT zeros, not small numbers
    assert np.abs(np.asarray(gx)[:, np.asarray(im) == 0]).max() == 0.0
    assert np.abs(np.asarray(gw)[np.asarray(im) == 0, :]).max() == 0.0
    assert np.abs(np.asarray(gw)[:, np.asarray(om) == 0]).max() == 0.0


def test_vjp_batched_vmap_per_row_masks():
    """One vmapped program serves heterogeneous retentions: per-row masks."""
    rng = np.random.default_rng(7)
    B, M, K, N = 3, 40, 96, 48
    xs = jnp.asarray(rng.normal(size=(B, M, K)), jnp.float32)
    ws = jnp.asarray(rng.normal(size=(B, K, N)) * 0.05, jnp.float32)
    ims = np.zeros((B, K), np.float32)
    oms = np.zeros((B, N), np.float32)
    for b, keep in enumerate((1.0, 0.5, 0.25)):   # prefix retentions
        ims[b, : max(1, int(K * keep))] = 1.0
        oms[b, : max(1, int(N * keep))] = 1.0
    ims, oms = jnp.asarray(ims), jnp.asarray(oms)

    f = jax.vmap(
        lambda a, b_, c, d: pruned_matmul(
            a, b_, c, d, block_m=32, block_n=16, block_k=16, interpret=True
        )
    )
    ref = jnp.einsum("bmk,bkn->bmn", xs * ims[:, None, :], ws) * oms[:, None, :]
    np.testing.assert_allclose(np.asarray(f(xs, ws, ims, oms)), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    gw = jax.grad(lambda w_: jnp.sum(f(xs, w_, ims, oms) ** 2))(ws)
    gr = jax.grad(lambda w_: jnp.sum(
        (jnp.einsum("bmk,bkn->bmn", xs * ims[:, None, :], w_) * oms[:, None, :]) ** 2
    ))(ws)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gr), atol=1e-4, rtol=1e-4)
    assert np.abs(np.asarray(gw)[2][:, np.asarray(oms)[2] == 0]).max() == 0.0


# ---------------------------------------------------------------------------
# model-level lowering
# ---------------------------------------------------------------------------

def _prefix_masks(cfg, params, keep):
    out = {}
    for name in prunable_layer_names(cfg):
        n = params[f"{name}/bn_g"].shape[0]
        m = np.zeros(n, np.float32)
        m[: max(2, int(round(n * keep)))] = 1.0
        out[name] = m
    return out


def _mask_params(params, cfg, unit_masks):
    """Apply unit masks to params the way the fleet's mask stack does."""
    from repro.core.aggregation import coordinate_mask
    from repro.models.cnn import build_unit_space

    space, unit_map = build_unit_space(cfg, {k: np.asarray(v) for k, v in params.items()})
    index = {
        l.name: np.flatnonzero(unit_masks[l.name]).astype(np.int64)
        for l in space.layers
    }
    shapes = {k: v.shape for k, v in params.items()}
    return {
        k: jnp.asarray(v)
        * jnp.asarray(coordinate_mask(k, index, unit_map, shapes).astype(np.float32))
        for k, v in params.items()
    }


@pytest.mark.parametrize(
    "kind",
    ["vgg", pytest.param("resnet", marks=pytest.mark.slow)],
)
def test_cnn_apply_block_skip_matches_dense(kind):
    if kind == "vgg":
        cfg = vgg_config("t", [32, "M", 64], num_classes=10, image_size=8)
    else:
        cfg = resnet_config("t", 8, [(1, 8), (1, 16)], num_classes=10,
                            image_size=8, bottleneck=True)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    um = _prefix_masks(cfg, params, keep=0.5)
    pm = _mask_params(params, cfg, um)
    umj = {k: jnp.asarray(v) for k, v in um.items()}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, 3))

    dense = cnn_apply(pm, cfg, x)
    bs = cnn_apply(pm, cfg, x, compute="block_skip", unit_masks=umj,
                   blocks=(128, 8, 8), interpret=True)
    np.testing.assert_allclose(np.asarray(bs), np.asarray(dense), atol=1e-4, rtol=1e-4)

    def loss(fn_params, compute):
        kw = ({"compute": "block_skip", "unit_masks": umj, "blocks": (128, 8, 8),
               "interpret": True} if compute == "block_skip" else {})
        return jnp.sum(jax.nn.log_softmax(cnn_apply(fn_params, cfg, x, **kw)))

    gb = jax.grad(lambda p: loss(p, "block_skip"))(pm)
    gd = jax.grad(lambda p: loss(p, "dense"))(pm)
    for k in gb:
        np.testing.assert_allclose(np.asarray(gb[k]), np.asarray(gd[k]),
                                   atol=1e-4, rtol=1e-4, err_msg=k)


# ---------------------------------------------------------------------------
# fleet-level equivalence + the FLOPs ledger
# ---------------------------------------------------------------------------

def _sim(compute, rate):
    cnn = vgg_config("t", [32, "M", 64], num_classes=10, image_size=8)
    task = SyntheticImageTask(num_classes=10, image_size=8, train_size=64,
                              test_size=64, seed=0)
    return run_simulation(SimConfig(
        method="adaptcl", engine="masked", compute=compute,
        compute_blocks=(128, 8, 8), importance="index",
        rounds=3, prune_interval=1, num_workers=2, batch_size=8,
        local_epochs=1.0, cnn=cnn, task=task, eval_every=3,
        fixed_pruned_rates=[[rate] * 2, [0.0] * 2, [0.0] * 2], seed=3,
    ))


@pytest.fixture(scope="module")
def sims():
    # rate 0.74 realizes retention ~0.25 under the index-prefix importance
    return _sim("dense", 0.74), _sim("block_skip", 0.74)


@pytest.mark.slow
def test_engine_equivalence_dense_vs_block_skip(sims):
    dense, bs = sims
    assert abs(dense.final_acc - bs.final_acc) <= 1e-3
    for k in dense.global_params:
        np.testing.assert_allclose(bs.global_params[k], dense.global_params[k],
                                   atol=1e-4, err_msg=k)
    assert bs.compute == "block_skip" and dense.compute == "dense"
    assert bs.recompiles == dense.recompiles  # block-skip adds no shapes


@pytest.mark.slow
def test_flops_executed_tracks_retention(sims):
    dense, bs = sims
    assert 0.2 < np.mean(bs.retentions) < 0.3   # the rate landed where tuned
    # dense masked programs execute the base shapes -> executed > ideal
    assert dense.flops_executed > 1.2 * dense.flops_ideal
    # block_skip reports <= 1.1x the reconfigured ideal at retention ~0.25
    assert bs.flops_executed <= 1.1 * bs.flops_ideal
    assert bs.flops_ideal == dense.flops_ideal  # same schedule, same sub-models
    assert bs.blocks_executed > 0


def test_retention_quarter_executes_under_half_the_blocks():
    """The bench claim, host-side: prefix masks at retention 0.25 execute
    < 0.5x the kernel grid cells of retention 1.0 (per image)."""
    cfg = vgg_config("t", [32, "M", 64], num_classes=10, image_size=8)
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    full = cnn_block_compute(cfg, _prefix_masks(cfg, params, 1.0), (128, 8, 8))
    quarter = cnn_block_compute(cfg, _prefix_masks(cfg, params, 0.25), (128, 8, 8))
    assert quarter["blocks"] < 0.5 * full["blocks"]
    assert full["blocks"] == full["blocks_total"]   # nothing skipped at 1.0
