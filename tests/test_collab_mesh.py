"""Mesh-level collaborative round (core.collab): By-worker psum semantics
must match the host-level aggregation exactly."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_by_worker, extract_subparams
from repro.core.collab import collab_round, make_worker_masks
from repro.core.masks import UnitLayer, UnitSpace, full_index, prune_to_budget

SPACE = UnitSpace(layers=(UnitLayer("u", 8, 4),), fixed_params=6)
UNIT_MAP = {"w": [("u", 1)]}


def _loss(params, x, y):
    logits = x @ params["w"] + params["b"]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


def test_collab_round_matches_host_aggregation():
    mesh = jax.make_mesh((1,), ("data",))  # 1 CPU device = 1 worker slice
    rng = np.random.default_rng(0)
    base = {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
            "b": jnp.zeros((8,), jnp.float32)}
    base_shapes = {k: v.shape for k, v in base.items()}
    scores = {"u": np.arange(8, dtype=np.float64)}
    idx = prune_to_budget(full_index(SPACE), scores, 0.4, SPACE)
    masks = make_worker_masks([idx], {"w": [("u", 1)], "b": [("u", 0)]}, base_shapes)

    x = jnp.asarray(rng.normal(size=(64, 4)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 8, 64), jnp.int32)

    out = collab_round(_loss, base, masks, x, y, mesh, lr=0.1, steps=2, batch_size=32)

    # host-level reference: same masked SGD then By-worker aggregation
    from repro.core.collab import local_sgd_steps

    m = jax.tree.map(lambda a: a[0], masks)
    theta = jax.tree.map(lambda g, mm: g * mm, base, m)

    def masked_loss(p, xb, yb):
        return _loss(jax.tree.map(lambda w, mm: w * mm, p, m), xb, yb)

    theta = local_sgd_steps(masked_loss, theta, x, y, lr=0.1, steps=2, batch_size=32)
    theta = jax.tree.map(lambda w, mm: w * mm, theta, m)
    for k in base:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(theta[k]), atol=1e-6)
    # pruned coordinates are exact zeros after aggregation (By-worker)
    pruned_cols = np.setdiff1d(np.arange(8), np.asarray(idx["u"]))
    assert np.abs(np.asarray(out["w"])[:, pruned_cols]).max() == 0.0


def test_collab_round_traces_with_collective():
    """The aggregation psum must appear in the traced jaxpr (on a 1-device
    CPU mesh the lowered HLO legally elides it — num_partitions=1)."""
    mesh = jax.make_mesh((1,), ("data",))
    base = {"w": jnp.ones((4, 8), jnp.float32), "b": jnp.zeros((8,), jnp.float32)}
    masks = make_worker_masks(
        [full_index(SPACE)], {"w": [("u", 1)], "b": [("u", 0)]},
        {k: v.shape for k, v in base.items()},
    )
    x = jnp.ones((32, 4), jnp.float32)
    y = jnp.zeros((32,), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda g, m, xx, yy: collab_round(_loss, g, m, xx, yy, mesh, steps=1)
    )(base, masks, x, y)
    assert "psum" in str(jaxpr)
