"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward + one train step on CPU, shape + finiteness assertions, and
exact incremental-decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.models import transformer as T
from repro.models.config import apply_retention, param_count
from repro.optim.optimizers import adamw, apply_updates

pytestmark = pytest.mark.slow  # one jit per arch x test; quick pass skips

ARCHS = list_archs()


def _batch(cfg, key, b=2, s=24):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.num_prefix_embeds:
        batch["prefix_embeds"] = (
            jax.random.normal(key, (b, cfg.num_prefix_embeds, cfg.d_model)) * 0.02
        )
    if cfg.encoder_layers:
        batch["enc_embeds"] = jax.random.normal(key, (b, 16, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = T.forward(params, cfg, batch)
    assert logits.shape == (2, 24, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()

    opt = adamw(1e-3)
    opt_state = opt.init(params)
    loss, grads = jax.value_and_grad(lambda p: T.lm_loss(p, cfg, batch))(params)
    assert np.isfinite(float(loss))
    updates, opt_state = opt.update(grads, opt_state, params)
    new_params = apply_updates(params, updates)
    loss2 = T.lm_loss(new_params, cfg, batch)
    assert np.isfinite(float(loss2))
    # one step on a random batch should reduce loss at init (lr small)
    leaves = jax.tree.leaves(new_params)
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)
    toks = batch["tokens"]
    full_logits, _ = T.forward(params, cfg, batch)
    s0 = toks.shape[1] - 4
    pre = dict(batch)
    pre["tokens"] = toks[:, :s0]
    lg, state = T.prefill(params, cfg, pre, max_len=64)
    errs = [float(np.abs(np.asarray(lg) - np.asarray(full_logits[:, s0 - 1])).max())]
    for i in range(s0, toks.shape[1]):
        lg, state = T.decode_step(params, cfg, state, toks[:, i])
        errs.append(float(np.abs(np.asarray(lg) - np.asarray(full_logits[:, i])).max()))
    assert max(errs) < 2e-3, f"incremental decode diverged: {errs}"


@pytest.mark.parametrize("arch", ARCHS)
def test_apply_retention_shrinks(arch):
    cfg = smoke_config(arch)
    full = param_count(cfg)
    sub_cfg = apply_retention(cfg, 0.5, prune_heads=True)
    sub = param_count(sub_cfg)
    assert sub < full
    assert sub_cfg.num_heads % sub_cfg.num_kv_heads == 0  # GQA stays well-formed
    # reconfigured model must run
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, sub_cfg)
    logits, _ = T.forward(params, sub_cfg, _batch(sub_cfg, key))
    assert np.isfinite(np.asarray(logits)).all()
