"""Sharding rules + launch wiring (divisibility guarantees, input specs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import SHAPES, list_archs, smoke_config
from repro.models import transformer as T
from repro.sharding.specs import _assign, batch_pspecs, param_pspec, tree_pspecs


class FakeMesh:
    """Mesh stand-in exposing only .shape (param_pspec needs nothing else)."""

    def __init__(self, **axes):
        self.shape = dict(axes)


MESHES = [FakeMesh(data=16, model=16), FakeMesh(pod=2, data=16, model=16)]


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    """Every sharded dim of every param divides its mesh axes (all archs)."""
    cfg = smoke_config(arch).replace(dtype="bfloat16")
    params = jax.eval_shape(lambda: T.init_params(jax.random.key(0), cfg))
    specs = tree_pspecs(params, mesh, param_pspec)

    def check(path, leaf, spec):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            size = mesh.shape[ax] if isinstance(ax, str) else int(np.prod([mesh.shape[a] for a in ax]))
            assert leaf.shape[dim] % size == 0, (path, leaf.shape, spec)

    leaves_p, tree_p = jax.tree.flatten(params)
    leaves_s, _ = jax.tree.flatten(specs, is_leaf=lambda x: hasattr(x, "index"))
    # walk jointly
    flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: type(x).__name__ == "PartitionSpec")
    assert len(leaves_p) == len(flat_specs)
    for leaf, spec in zip(leaves_p, flat_specs):
        check("", leaf, spec)


def test_batch_pspec_falls_back_to_seq():
    mesh = FakeMesh(data=16, model=16)
    # batch 1 (long_500k) -> shard seq dim instead
    spec = batch_pspecs("tokens", (1, 524288), mesh)
    assert spec[0] is None and spec[1] in ("data", ("data",))
    spec = batch_pspecs("tokens", (256, 4096), mesh)
    assert spec[0] in ("data", ("data",))


def test_assign_respects_divisibility():
    mesh = FakeMesh(data=16, model=16)
    # 8 heads cannot shard on 16-way model axis -> dropped
    spec = _assign((512, 8, 64), mesh, [(1, "model"), (0, "data")])
    assert spec[1] is None and spec[0] == "data"


def test_input_specs_cover_all_shapes():
    from repro.launch import dryrun as D

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("internlm2-1.8b", "whisper-small", "internvl2-76b"):
        cfg = smoke_config(arch).replace(dtype="bfloat16")
        for shape_name, shp in SHAPES.items():
            specs = D.input_specs(cfg, shape_name, mesh)
            if shp.kind in ("train", "prefill"):
                assert "batch" in specs and "tokens" in specs["batch"]
                tok = specs["batch"]["tokens"]
                assert tok.shape[0] == shp.global_batch
            else:
                assert "state" in specs and "token" in specs
                assert specs["token"].shape == (shp.global_batch,)


@pytest.mark.slow
def test_make_step_lowers_on_local_mesh():
    """End-to-end lowering of train + decode steps on a trivial mesh."""
    from repro.launch import dryrun as D

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = smoke_config("internlm2-1.8b").replace(dtype="float32")
    # shrink shapes: monkeypatch a tiny shape entry
    from repro.configs.base import SHAPES as SH, InputShape

    SH["tiny_train"] = InputShape("tiny_train", 32, 2, "train")
    SH["tiny_decode"] = InputShape("tiny_decode", 32, 2, "decode")
    try:
        for shape in ("tiny_train", "tiny_decode"):
            step, abstract_args = D.make_step(cfg, shape)
            with mesh:
                compiled = jax.jit(step).lower(*abstract_args(mesh)).compile()
            assert compiled.cost_analysis() is not None
    finally:
        SH.pop("tiny_train")
        SH.pop("tiny_decode")


def test_long500k_eligibility():
    from repro.launch.dryrun import long_500k_eligible
    from repro.configs import get_config

    assert long_500k_eligible(get_config("xlstm-1.3b"), None)
    assert long_500k_eligible(get_config("recurrentgemma-9b"), None)
    assert not long_500k_eligible(get_config("qwen3-32b"), None)
    assert long_500k_eligible(get_config("qwen3-32b"), "windowed")
    assert not long_500k_eligible(get_config("whisper-small"), None)
