"""Resident fleet state + scenario layer (sampling / dropout / churn).

Pins the PR-2 contract: the masked engine keeps all workers in base
coordinates end-to-end (zero extract/embed host round-trips inside the round
loop, one compile no matter what prunes or who participates), and scenarios
unfold identically under every engine."""
import numpy as np
import pytest

from repro.core.aggregation import aggregate_by_worker, extract_subparams
from repro.core.masks import full_index
from repro.core.scenario import (
    RoundEvents,
    ScenarioConfig,
    ScenarioEngine,
    full_participation,
)
from repro.core.simulation import SimConfig, _Env, run_simulation
from repro.core.timing import HeterogeneityConfig
from repro.core.worker import make_batch_plan
from repro.models.cnn import vgg_config

TINY = vgg_config("vgg_tiny_res", [8, "M", 16], num_classes=4, image_size=8)


def _cfg(engine, **kw):
    base = dict(
        method="adaptcl",
        engine=engine,
        rounds=3,
        prune_interval=2,
        num_workers=4,
        cnn=TINY,
        het=HeterogeneityConfig(num_workers=4, sigma=3.0),
        eval_every=1,
        seed=5,
    )
    base.update(kw)
    return SimConfig(**base)


def _events(active, dropped=None, joined=None):
    W = len(active)
    return RoundEvents(
        active=np.asarray(active, bool),
        dropped=np.zeros(W, bool) if dropped is None else np.asarray(dropped, bool),
        joined=np.zeros(W, bool) if joined is None else np.asarray(joined, bool),
    )


# ---------------------------------------------------------------------------
# scenario engine (quick)
# ---------------------------------------------------------------------------

def test_scenario_engine_draw_properties():
    cfg = ScenarioConfig(participation=0.5, dropout=0.9, churn=0.2, seed=1)
    eng = ScenarioEngine(cfg, 10)
    for t in range(1, 30):
        ev = eng.draw(t)
        assert ev.active.sum() == 5
        assert ev.submitters.sum() >= 1        # timeout never starves a round
        assert not (ev.dropped & ~ev.active).any()


def test_scenario_schedule_passthrough_and_tail():
    sched = [_events([1, 0, 1])]
    eng = ScenarioEngine(ScenarioConfig(schedule=sched), 3)
    ev = eng.draw(1)
    assert list(ev.active) == [True, False, True]
    tail = eng.draw(2)                          # beyond schedule: everyone in
    assert tail.active.all() and not tail.dropped.any() and not tail.joined.any()


def test_scenario_config_validation():
    with pytest.raises(ValueError):
        ScenarioEngine(ScenarioConfig(participation=0.0), 4)
    with pytest.raises(ValueError):
        ScenarioEngine(ScenarioConfig(dropout=1.0), 4)
    with pytest.raises(ValueError):
        ScenarioEngine(ScenarioConfig(min_participants=0), 4)
    # a sub-1 straggler deadline would end rounds before their own
    # submitters finish; rejected by name at engine construction
    with pytest.raises(ValueError, match="timeout_factor"):
        ScenarioEngine(ScenarioConfig(timeout_factor=0.9), 4)
    ScenarioEngine(ScenarioConfig(timeout_factor=1.0), 4)   # boundary is legal
    # async methods accept client sampling and dropout (timed-out commits;
    # see tests/test_async_fused.py) but reject churn — and the churn error
    # must not blame dropout
    with pytest.raises(ValueError, match="churn") as exc:
        run_simulation(_cfg("masked", method="fedasync_s",
                            scenario=ScenarioConfig(churn=0.2)))
    assert "dropout" not in str(exc.value)
    with pytest.raises(ValueError, match="churn"):
        run_simulation(_cfg("masked", method="ssp_s",
                            scenario=ScenarioConfig(churn=0.2)))
    with pytest.raises(ValueError):   # scripted schedules are sync-only too
        run_simulation(_cfg("masked", method="dcasgd_s",
                            scenario=ScenarioConfig(schedule=[_events([1, 1, 1, 1])])))


def test_schedule_rounds_are_normalized():
    """Scheduled events obey the same invariants as random draws: at least
    one submitter survives the timeout, and an empty round is rejected."""
    eng = ScenarioEngine(
        ScenarioConfig(schedule=[_events([1, 1, 0, 1], dropped=[1, 1, 0, 1])]), 4
    )
    ev = eng.draw(1)
    assert ev.submitters.sum() == 1 and ev.submitters[0]
    empty = ScenarioEngine(ScenarioConfig(schedule=[_events([0, 0, 0, 0])]), 4)
    with pytest.raises(ValueError):
        empty.draw(1)


# ---------------------------------------------------------------------------
# resident engine (simulator level)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_resident_masked_matches_sequential_with_zero_roundtrips():
    seq = run_simulation(_cfg("sequential"))
    res = run_simulation(_cfg("masked"))
    assert res.final_acc == pytest.approx(seq.final_acc, abs=1e-3)
    assert res.total_time == pytest.approx(seq.total_time, rel=1e-9)
    assert res.retentions == pytest.approx(seq.retentions)
    for k in seq.global_params:
        np.testing.assert_allclose(
            res.global_params[k], seq.global_params[k], atol=1e-3, err_msg=k
        )
    # the resident contract: no extract/embed inside the round loop, and the
    # whole run (pruning events included) compiles exactly one program
    assert res.host_roundtrips == 0
    assert res.recompiles == 1
    assert seq.host_roundtrips > 0              # reference engine round-trips


@pytest.mark.slow
def test_participation_round_equals_sequential_over_sampled_workers():
    """One sampled round (C<1, one dropout) == training only the sampled
    workers sequentially and averaging the submitters."""
    active, dropped = [1, 1, 0, 1], [0, 1, 0, 0]
    scen = ScenarioConfig(schedule=[_events(active, dropped)])
    sim = _cfg("masked", method="fedavg_s", rounds=1, scenario=scen)
    res = run_simulation(sim)
    assert res.host_roundtrips == 0
    assert res.scenario_rounds == [(1, 3, 1, 0)]

    # manual reference: same env fixture, same plan stream, sampled workers
    # through the one-worker trainer, submitters averaged with 1/|S|
    ref_env = _Env(_cfg("sequential", method="fedavg_s", rounds=1))
    full = full_index(ref_env.space)
    trained = {}
    for w in [0, 1, 3]:                         # active workers, worker order
        x, y = ref_env.shard_xy(w)
        plan = make_batch_plan(len(x), sim.batch_size, sim.local_epochs, ref_env.rng)
        make_batch_plan(len(x), sim.batch_size, 0.0, ref_env.rng)   # phase-B draw
        params = extract_subparams(ref_env.base_params, full, ref_env.unit_map)
        trained[w], _ = ref_env.trainer.train_plan(
            params, ref_env.unit_map, x, y, plan, sim.lam
        )
    expected = aggregate_by_worker(
        [(trained[w], full) for w in [0, 3]],    # submitters only
        ref_env.unit_map, ref_env.base_shapes,
    )
    for k in expected:
        np.testing.assert_allclose(
            res.global_params[k], expected[k].astype(np.float32), atol=1e-4,
            err_msg=k,
        )


@pytest.mark.slow
def test_scenario_identical_across_engines():
    scen = ScenarioConfig(participation=0.5, dropout=0.2, churn=0.1, seed=3)
    kw = dict(rounds=4, num_workers=6,
              het=HeterogeneityConfig(num_workers=6, sigma=3.0), scenario=scen)
    seq = run_simulation(_cfg("sequential", **kw))
    res = run_simulation(_cfg("masked", **kw))
    assert res.scenario_rounds == seq.scenario_rounds
    assert res.total_time == pytest.approx(seq.total_time, rel=1e-9)
    assert res.retentions == pytest.approx(seq.retentions)
    for k in seq.global_params:
        np.testing.assert_allclose(
            res.global_params[k], seq.global_params[k], atol=1e-3, err_msg=k
        )


@pytest.mark.slow
def test_churn_keeps_retentions_and_shapes_consistent():
    W = 4
    sched = [
        _events([1] * W),
        _events([1] * W),
        _events([1] * W),                        # worker 0 prunes here (PI=2)
        _events([1] * W, joined=[1, 0, 0, 0]),   # ... then its slot churns
    ]
    r = run_simulation(_cfg("masked", rounds=4, scenario=ScenarioConfig(schedule=sched)))
    assert len(r.retentions) == W
    assert r.retentions[0] == pytest.approx(1.0)     # fresh worker: full model
    assert all(0.0 < g <= 1.0 + 1e-9 for g in r.retentions)
    base = _Env(_cfg("sequential")).base_shapes
    assert {k: v.shape for k, v in r.global_params.items()} == base
    assert r.host_roundtrips == 0


@pytest.mark.slow
def test_sampling_plus_pruning_keeps_single_compile():
    scen = ScenarioConfig(participation=0.5, dropout=0.25, seed=11)
    r = run_simulation(_cfg("masked", rounds=6, num_workers=8,
                            het=HeterogeneityConfig(num_workers=8, sigma=4.0),
                            scenario=scen))
    assert r.recompiles == 1
    assert r.host_roundtrips == 0
    assert any(g < 1.0 for g in r.retentions)        # pruning really happened


# ---------------------------------------------------------------------------
# async window batching
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("method", ["fedasync_s", "ssp_s"])
def test_async_window_batches_fleet_calls(method):
    kw = dict(method=method, rounds=3, num_workers=6,
              het=HeterogeneityConfig(num_workers=6, sigma=3.0), eval_every=2)
    serial = run_simulation(_cfg("masked", async_window=0.0, **kw))
    windowed = run_simulation(_cfg("masked", async_window=50.0, **kw))
    # same number of commits either way...
    assert len(windowed.acc_time) == len(serial.acc_time)
    # ...but the windowed run coalesces them into far fewer device programs
    assert windowed.batched_calls < serial.batched_calls
    assert 0.0 <= windowed.best_acc <= 1.0
