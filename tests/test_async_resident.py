"""Async resident schedulers: the PR-3 engine-equivalence harness.

Pins three contracts:

1. **Scheduler equivalence** — the resident (masked) async path must match
   the per-worker reference for every scheduler (``fedasync_s`` / ``ssp_s``
   / ``dcasgd_s``): identical virtual clocks (the event queue, channel model
   and RNG streams are shared), final params within 1e-3, and ZERO
   extract/embed/merge host round-trips — while the per-worker baseline now
   honestly tallies one ``async_merge`` per commit.
2. **Staleness-weighting goldens** — the polynomial fedasync weights, the
   SSP delta rule and the DC-ASGD compensation are pinned against literal
   expected values over scripted commit schedules, so the stacked rewrite
   (or any future one) cannot silently change the merge semantics.
3. **Participation-sized compute** — sampled scenarios and async window
   batches gather only the active rows into power-of-two-bucketed
   sub-stacks; recompiles stay bounded by the number of bucket sizes
   actually launched (``SimResult.bucket_sizes``).
"""
import numpy as np
import pytest

from repro.core.aggregation import AsyncServer, fedasync_weight
from repro.core.fleet import bucket_rows
from repro.core.scenario import RoundEvents, ScenarioConfig
from repro.core.simulation import SimConfig, run_simulation
from repro.core.timing import HeterogeneityConfig
from repro.models.cnn import vgg_config

TINY = vgg_config("vgg_tiny_async", [8, "M", 16], num_classes=4, image_size=8)
ASYNC_METHODS = ("fedasync_s", "ssp_s", "dcasgd_s")


def _cfg(engine, method="fedasync_s", **kw):
    W = kw.pop("num_workers", 4)
    base = dict(
        method=method,
        engine=engine,
        rounds=2,
        num_workers=W,
        cnn=TINY,
        het=HeterogeneityConfig(num_workers=W, sigma=3.0),
        eval_every=2,
        seed=5,
    )
    base.update(kw)
    return SimConfig(**base)


# ---------------------------------------------------------------------------
# golden regression: staleness weighting math (quick)
# ---------------------------------------------------------------------------

def test_fedasync_polynomial_weights_golden():
    """a = a0 * (s + 1)^-0.5, pinned for a scripted staleness ladder."""
    expected = {
        0: 0.5,
        1: 0.3535533905932738,
        2: 0.28867513459481287,
        5: 0.2041241452319315,
        10: 0.15075567228888181,
    }
    for s, want in expected.items():
        assert fedasync_weight(0.5, s) == pytest.approx(want, abs=1e-12)
    # a0 scales linearly; staleness 0 commits at full mixing weight
    assert fedasync_weight(0.8, 0) == pytest.approx(0.8, abs=1e-12)


def test_fedasync_scripted_merge_golden():
    srv = AsyncServer("fedasync_s", {"w": np.array([1.0])}, 4, fedasync_a=0.5)
    g0 = {"w": np.array([1.0])}
    srv.commit(0, {"w": np.array([2.0])}, g0, 0)       # a=0.5
    assert srv.params["w"][0] == pytest.approx(1.5, abs=1e-12)
    srv.commit(1, {"w": np.array([3.0])}, g0, 3)       # a=0.5*4^-0.5=0.25
    assert srv.params["w"][0] == pytest.approx(0.75 * 1.5 + 0.25 * 3.0, abs=1e-12)
    assert srv.params["w"][0] == pytest.approx(1.875, abs=1e-12)
    assert srv.version == 2


def test_ssp_scripted_merge_golden():
    srv = AsyncServer("ssp_s", {"w": np.array([1.0])}, 4)
    out = srv.commit(2, {"w": np.array([3.0])}, {"w": np.array([1.0])}, 5)
    assert out["w"][0] == pytest.approx(1.0 + (3.0 - 1.0) / 4, abs=1e-12)
    out = srv.commit(0, {"w": np.array([0.5])}, {"w": np.array([1.5])}, 0)
    assert out["w"][0] == pytest.approx(1.5 - 1.0 / 4, abs=1e-12)
    # under client sampling, SSP's delta average is over the committing
    # cohort, not the slot pool
    srv = AsyncServer("ssp_s", {"w": np.array([1.0])}, 200, cohort_size=2)
    out = srv.commit(7, {"w": np.array([3.0])}, {"w": np.array([1.0])}, 0)
    assert out["w"][0] == pytest.approx(2.0, abs=1e-12)


def test_dcasgd_compensation_golden():
    """DC-ASGD-a over a scripted 3-commit schedule (lr=0.1, lambda=2, m=.95):
    expected globals pinned from the reference per-worker semantics."""
    g0 = {"w": np.array([1.0, -2.0])}
    srv = AsyncServer(
        "dcasgd_s", g0, 2, lr=0.1, dcasgd_lambda=2.0, dcasgd_m=0.95
    )
    fetched = {0: dict(srv.params), 1: dict(srv.params)}
    script = [
        (0, [0.8, -1.9], [0.8, -1.9]),
        (1, [1.1, -2.2], [0.98101915, -2.26203830]),
        (0, [0.7, -1.6], [0.82884827, -1.02296241]),
    ]
    for w, trained, want in script:
        out = srv.commit(w, {"w": np.array(trained)}, fetched[w], 0)
        np.testing.assert_allclose(out["w"], want, atol=1e-6)
        fetched[w] = dict(srv.params)
    # w_bak tracks the post-commit global per worker row
    np.testing.assert_allclose(srv.backup["w"][0], srv.params["w"], atol=1e-12)


def test_async_server_rejects_unknown_method():
    srv = AsyncServer("fedasync_s", {"w": np.array([1.0])}, 2)
    srv.method = "nope"
    with pytest.raises(ValueError):
        srv.commit(0, {"w": np.array([1.0])}, {"w": np.array([1.0])}, 0)


# ---------------------------------------------------------------------------
# sub-stack buckets (quick)
# ---------------------------------------------------------------------------

def test_bucket_rows_powers_of_two_capped():
    assert bucket_rows(1, 10) == 1
    assert bucket_rows(2, 10) == 2
    assert bucket_rows(3, 10) == 4
    assert bucket_rows(5, 10) == 8
    assert bucket_rows(9, 10) == 10      # capped at the fleet size
    assert bucket_rows(10, 10) == 10
    with pytest.raises(ValueError):
        bucket_rows(0, 4)


# ---------------------------------------------------------------------------
# per-scheduler engine equivalence (simulator level)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("method", ASYNC_METHODS)
def test_resident_async_matches_per_worker(method):
    rounds = 4 if method == "ssp_s" else 2     # let SSP hit its blocking path
    seq = run_simulation(_cfg("sequential", method, rounds=rounds))
    res = run_simulation(_cfg("masked", method, rounds=rounds))
    # shared event queue + channel model: identical virtual clocks
    assert res.total_time == pytest.approx(seq.total_time, rel=1e-9)
    assert res.final_acc == pytest.approx(seq.final_acc, abs=1e-3)
    assert len(res.acc_time) == len(seq.acc_time)
    for k in seq.global_params:
        np.testing.assert_allclose(
            res.global_params[k], seq.global_params[k], atol=1e-3, err_msg=k
        )
    # resident contract: zero host round-trips in the async loop; the
    # per-worker baseline honestly reports one merge round-trip per commit
    assert res.host_roundtrips == 0
    assert seq.host_roundtrips >= rounds * 4


@pytest.mark.slow
def test_resident_windowed_async_matches_per_worker():
    kw = dict(async_window=30.0, rounds=3, num_workers=6)
    seq = run_simulation(_cfg("sequential", "fedasync_s", **kw))
    res = run_simulation(_cfg("masked", "fedasync_s", **kw))
    assert res.total_time == pytest.approx(seq.total_time, rel=1e-9)
    for k in seq.global_params:
        np.testing.assert_allclose(
            res.global_params[k], seq.global_params[k], atol=1e-3, err_msg=k
        )
    assert res.host_roundtrips == 0
    # window batches land as bucketed sub-stacks; compiles bounded by buckets
    assert res.recompiles <= len(res.bucket_sizes)


# ---------------------------------------------------------------------------
# participation-sized compute + recompile bounds
# ---------------------------------------------------------------------------

def test_async_sampling_zero_roundtrips_and_bucket_bound():
    """C=0.5 async sampling: only the sampled participants enter the event
    loop, sub-stacks are sized to them, recompiles bounded by buckets."""
    r = run_simulation(_cfg(
        "masked", "fedasync_s", rounds=2, num_workers=8,
        scenario=ScenarioConfig(participation=0.5, seed=2),
        async_window=30.0,
    ))
    assert r.host_roundtrips == 0
    assert r.recompiles <= len(r.bucket_sizes)
    assert max(r.bucket_sizes) <= 4          # device compute ~ participants
    assert r.scenario_rounds == [(0, 4, 0, 0)]
    assert 0.0 <= r.final_acc <= 1.0


def test_resident_async_zero_epoch_plans_commit_fetched_params():
    """local_epochs=0 draws empty plans everywhere: the resident path must
    commit the fetched params unchanged (like the per-worker engines), not
    crash on the absent trained sub-stack."""
    r = run_simulation(_cfg("masked", "fedasync_s", rounds=1, num_workers=2,
                            local_epochs=0.0))
    assert r.host_roundtrips == 0
    assert 0.0 <= r.final_acc <= 1.0


@pytest.mark.slow
def test_sync_participation_sized_compute_recompile_bound():
    """Varying sampled cohorts + pruning under the resident sync engine:
    active rows are gathered into bucketed sub-stacks (FLOPs track
    participation) and recompiles stay bounded by the bucket count, while
    the trained model still matches the sequential reference."""
    W = 8

    def ev(active):
        a = np.zeros(W, bool)
        a[list(active)] = True
        return RoundEvents(
            active=a, dropped=np.zeros(W, bool), joined=np.zeros(W, bool)
        )

    sched = [
        ev([0, 1]),                      # bucket 2
        ev([2, 3, 4]),                   # bucket 4
        ev(range(W)),                    # full stack (prune round, PI=2)
        ev([1, 2, 3, 4, 5]),             # bucket 8
        ev(range(W)),                    # full again
        ev([6, 7]),                      # bucket 2 (reused shape)
    ]
    kw = dict(
        method="adaptcl", rounds=len(sched), prune_interval=2, num_workers=W,
        scenario=ScenarioConfig(schedule=sched),
    )
    seq = run_simulation(_cfg("sequential", **kw))
    res = run_simulation(_cfg("masked", **kw))
    assert res.host_roundtrips == 0
    assert set(res.bucket_sizes) <= {2, 4, 8}
    assert res.recompiles <= len(res.bucket_sizes)
    assert res.scenario_rounds == seq.scenario_rounds
    assert res.total_time == pytest.approx(seq.total_time, rel=1e-9)
    for k in seq.global_params:
        np.testing.assert_allclose(
            res.global_params[k], seq.global_params[k], atol=1e-3, err_msg=k
        )
