"""Mesh-sharded fleet contracts (fused sync engine over a device mesh).

Pins down, on an 8-virtual-CPU-device mesh (``tests/conftest.py`` forces
``--xla_force_host_platform_device_count=8``):

  * sharded-fused == fused == sequential — final acc within 1e-3,
    ``prune_events`` BIT-identical, identical scenario event streams and
    channel draws (``update_times`` exact), for every mesh size that
    divides W, including under sampling / dropout / churn and the
    device-scored l1/taylor importance criteria;
  * the degenerate 1-device mesh is exactly the no-mesh engine;
  * host-dispatch economics stay O(R / round_fusion) FLAT in device count
    — sharding multiplies devices, not launches;
  * ``SimResult`` records the mesh (``n_devices`` / ``fleet_axis_size`` /
    ``shard_spec``), defaulting to 1/1/None on single-device runs;
  * the global -> (shard, local) index algebra behind shard-aware cohort
    gathers (``fleet.global_to_shard_local``, ``scenario.shard_cohorts``,
    ``bucket_rows(multiple=)``) and the bounds checks that keep a raw
    device ``take`` from silently clamping out-of-shard rows;
  * two-tier aggregation (per-shard partial reduce + global psum) matches
    the single-device reduction on real stacks;
  * unsupported-config guards: mesh requires the fused sync engine, a
    divisible W, and a fleet axis the mesh actually has.
"""
import numpy as np
import pytest

from repro.core.aggregation import (
    aggregate_by_unit_stacked_jnp,
    aggregate_by_worker_stacked_jnp,
)
from repro.core.fleet import (
    bucket_rows,
    gather_stack_rows,
    global_to_shard_local,
    scatter_stack_rows,
)
from repro.core.scenario import ScenarioConfig, shard_cohorts
from repro.core.simulation import SimConfig, run_simulation
from repro.core.timing import HeterogeneityConfig
from repro.models.cnn import vgg_config

TINY = vgg_config("vgg_tiny_fused", [8, "M", 16], num_classes=4, image_size=8)


def _sim(engine, mesh=None, **kw):
    base = dict(
        method="adaptcl",
        engine=engine,
        rounds=6,
        prune_interval=2,
        num_workers=8,          # divides every mesh size we build (1..8)
        batch_size=16,
        cnn=TINY,
        het=HeterogeneityConfig(num_workers=8, sigma=3.0),
        eval_every=2,
        seed=5,
    )
    base.update(kw)
    return run_simulation(SimConfig(mesh=mesh, **base))


def _mesh(n_dev):
    from repro.launch.mesh import make_fleet_mesh

    return make_fleet_mesh(n_dev)


def _assert_equivalent(ref, sharded):
    assert abs(ref.final_acc - sharded.final_acc) <= 1e-3
    assert ref.scenario_rounds == sharded.scenario_rounds
    assert ref.prune_events == sharded.prune_events
    np.testing.assert_allclose(
        np.array(ref.update_times), np.array(sharded.update_times),
        rtol=0, atol=0, equal_nan=True,
    )
    assert ref.total_time == pytest.approx(sharded.total_time, abs=1e-9)


# ---------------------------------------------------------------------------
# equivalence: sharded-fused == fused == sequential
# ---------------------------------------------------------------------------

def test_sharded_matches_fused_and_sequential(eight_devices):
    seq = _sim("sequential")
    fus = _sim("fused")
    shd = _sim("fused", mesh=_mesh(8))
    _assert_equivalent(seq, shd)
    _assert_equivalent(fus, shd)
    assert len(shd.prune_events) > 0


def test_sharded_scenario_streams_identical(eight_devices):
    scen = ScenarioConfig(participation=0.8, dropout=0.2, churn=0.15, seed=2)
    fus = _sim("fused", scenario=scen)
    shd = _sim("fused", mesh=_mesh(4), scenario=scen)
    _assert_equivalent(fus, shd)
    assert len(shd.scenario_rounds) == 6


def test_one_device_mesh_is_the_no_mesh_engine(eight_devices):
    """Degenerate golden: a 1-device mesh runs the same program modulo the
    shard_map wrapper — everything the channel/scenario/prune layers see is
    exact, and the mesh is still recorded in the result."""
    ref = _sim("fused")
    one = _sim("fused", mesh=_mesh(1))
    _assert_equivalent(ref, one)
    assert one.n_devices == 1 and one.fleet_axis_size == 1
    assert one.shard_spec == "PartitionSpec('fleet')"
    assert ref.shard_spec is None


@pytest.mark.slow
@pytest.mark.parametrize("importance", ["l1", "taylor"])
def test_sharded_importance_criteria(importance, eight_devices):
    # l1/taylor scores are computed ON DEVICE inside the sharded scan; the
    # reductions are row-local, so sharding the row axis cannot reorder the
    # removal walk — retained sets stay bit-identical to the host path
    seq = _sim("sequential", importance=importance)
    shd = _sim("fused", mesh=_mesh(8), importance=importance)
    _assert_equivalent(seq, shd)


@pytest.mark.slow
def test_sharded_by_unit_aggregation(eight_devices):
    # by_unit divides AFTER both psum tiers (num and den reduce globally
    # before the ratio) — pinned against the sequential host reference
    seq = _sim("sequential", aggregation="by_unit")
    shd = _sim("fused", mesh=_mesh(8), aggregation="by_unit")
    _assert_equivalent(seq, shd)


@pytest.mark.slow
def test_sharded_dgc_and_regrow(eight_devices):
    # device DGC is all row-local math (per-row top-|.| over the shard's own
    # residual stacks) and regrow is a host boundary step — neither crosses
    # rows, so keep sets, payload clocks and grow events survive sharding
    # bit-for-bit
    from repro.core.simulation import RegrowConfig

    kw = dict(dgc_sparsity=0.5, regrow=RegrowConfig(interval=2, alpha0=0.3),
              eval_every=6)
    fus = _sim("fused", **kw)
    shd = _sim("fused", mesh=_mesh(4), **kw)
    _assert_equivalent(fus, shd)
    assert fus.comm_bytes == shd.comm_bytes


# ---------------------------------------------------------------------------
# host-dispatch economics: flat in device count
# ---------------------------------------------------------------------------

def test_dispatches_flat_in_device_count(eight_devices):
    ref = _sim("fused", eval_every=6)
    for n_dev in (2, 8):
        shd = _sim("fused", mesh=_mesh(n_dev), eval_every=6)
        # same chunking, same jitted-launch count: sharding multiplies
        # devices, never dispatches
        assert shd.fused_chunks == ref.fused_chunks
        assert shd.host_dispatches == ref.host_dispatches
        assert shd.host_roundtrips == 0


def test_simresult_records_the_mesh(eight_devices):
    ref = _sim("fused", rounds=2, eval_every=2)
    shd = _sim("fused", mesh=_mesh(4), rounds=2, eval_every=2)
    assert (ref.n_devices, ref.fleet_axis_size, ref.shard_spec) == (1, 1, None)
    assert shd.n_devices == 4
    assert shd.fleet_axis_size == 4
    assert shd.shard_spec == "PartitionSpec('fleet')"


# ---------------------------------------------------------------------------
# global -> (shard, local) index algebra
# ---------------------------------------------------------------------------

def test_global_to_shard_local_mapping():
    shard, local = global_to_shard_local([0, 3, 4, 7], num_workers=8, num_shards=2)
    np.testing.assert_array_equal(shard, [0, 0, 1, 1])
    np.testing.assert_array_equal(local, [0, 3, 0, 3])
    # 1 shard: identity on locals
    shard, local = global_to_shard_local([5, 2], num_workers=8, num_shards=1)
    np.testing.assert_array_equal(shard, [0, 0])
    np.testing.assert_array_equal(local, [5, 2])
    with pytest.raises(ValueError, match="outside"):
        global_to_shard_local([8], num_workers=8, num_shards=2)
    with pytest.raises(ValueError, match="outside"):
        global_to_shard_local([-1], num_workers=8, num_shards=2)
    with pytest.raises(ValueError, match="divide"):
        global_to_shard_local([0], num_workers=6, num_shards=4)


def test_shard_cohorts_partitions_in_draw_order():
    cohort = [6, 1, 4, 3]   # a sampled cohort in draw order
    parts = shard_cohorts(cohort, num_workers=8, num_shards=2)
    assert len(parts) == 2
    np.testing.assert_array_equal(parts[0], [1, 3])   # slots 1,3 -> local
    np.testing.assert_array_equal(parts[1], [2, 0])   # slots 6,4 -> local
    # every slot lands exactly once
    total = sum(len(p) for p in parts)
    assert total == len(cohort)
    with pytest.raises(ValueError, match="outside"):
        shard_cohorts([9], num_workers=8, num_shards=2)


def test_bucket_rows_respects_shard_multiple():
    assert bucket_rows(3, 8) == 4                    # pow2, unchanged
    assert bucket_rows(3, 8, multiple=1) == 4
    assert bucket_rows(2, 8, multiple=8) == 8        # floored to shard count
    assert bucket_rows(5, 8, multiple=4) == 8        # pow2 >= pow2 divides
    assert bucket_rows(5, 12, multiple=3) == 9       # non-pow2 shards round up
    with pytest.raises(ValueError, match="divide"):
        bucket_rows(9, 10, multiple=4)               # cap itself non-divisible


def test_gather_scatter_reject_out_of_range_rows():
    import jax.numpy as jnp

    stacks = {"w": jnp.arange(12.0).reshape(4, 3)}
    sub = gather_stack_rows(stacks, np.array([2, 0]), num_rows=4)
    np.testing.assert_array_equal(np.asarray(sub["w"]), [[6, 7, 8], [0, 1, 2]])
    with pytest.raises(ValueError, match="GLOBAL"):
        gather_stack_rows(stacks, np.array([4]), num_rows=4)
    with pytest.raises(ValueError, match="GLOBAL"):
        scatter_stack_rows(stacks, np.array([-1]), sub, num_rows=4)


# ---------------------------------------------------------------------------
# two-tier aggregation: per-shard partial reduce + global psum
# ---------------------------------------------------------------------------

def test_two_tier_aggregation_matches_single_device(eight_devices):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.sharding.compat import shard_map_compat
    from repro.sharding.specs import fleet_sharding

    mesh = _mesh(4)
    rng = np.random.default_rng(7)
    W = 8
    stacks = {"a": rng.normal(size=(W, 3, 2)).astype(np.float32),
              "b": rng.normal(size=(W, 5)).astype(np.float32)}
    masks = {k: (rng.random(v.shape) > 0.3).astype(np.float32)
             for k, v in stacks.items()}
    weights = rng.random(W).astype(np.float32)
    submitters = (rng.random(W) > 0.2).astype(np.float32)

    ref_w = aggregate_by_worker_stacked_jnp(
        {k: jnp.asarray(v) for k, v in stacks.items()}, jnp.asarray(weights))
    ref_u = aggregate_by_unit_stacked_jnp(
        {k: jnp.asarray(v) for k, v in stacks.items()},
        {k: jnp.asarray(v) for k, v in masks.items()},
        jnp.asarray(submitters))

    sh = fleet_sharding(mesh)
    dstacks = {k: jax.device_put(v, sh) for k, v in stacks.items()}
    dmasks = {k: jax.device_put(v, sh) for k, v in masks.items()}

    two_w = shard_map_compat(
        lambda s, w: aggregate_by_worker_stacked_jnp(s, w, axis="fleet"),
        mesh=mesh, in_specs=(P("fleet"), P("fleet")), out_specs=P(),
    )(dstacks, jax.device_put(weights, sh))
    two_u = shard_map_compat(
        lambda s, m, sub: aggregate_by_unit_stacked_jnp(s, m, sub, axis="fleet"),
        mesh=mesh, in_specs=(P("fleet"), P("fleet"), P("fleet")), out_specs=P(),
    )(dstacks, dmasks, jax.device_put(submitters, sh))

    for k in stacks:
        np.testing.assert_allclose(
            np.asarray(two_w[k]), np.asarray(ref_w[k]), rtol=0, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(two_u[k]), np.asarray(ref_u[k]), rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# unsupported-config guards
# ---------------------------------------------------------------------------

def test_mesh_requires_fused_sync_engine(eight_devices):
    with pytest.raises(ValueError, match="fused"):
        _sim("masked", mesh=_mesh(2), rounds=2)
    with pytest.raises(ValueError, match="fused"):
        _sim("fused", mesh=_mesh(2), method="fedasync_s", rounds=2)


def test_mesh_requires_divisible_fleet(eight_devices):
    with pytest.raises(ValueError, match="divide"):
        _sim("fused", mesh=_mesh(8), num_workers=5,
             het=HeterogeneityConfig(num_workers=5, sigma=3.0), rounds=2)


def test_mesh_requires_fleet_axis(eight_devices):
    import jax

    bad = jax.make_mesh((2,), ("data",))
    with pytest.raises(ValueError, match="fleet"):
        _sim("fused", mesh=bad, rounds=2)
