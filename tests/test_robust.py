"""Robust aggregation layer contracts (core.aggregation + core.faults).

Pins down:
  * config validation names the offending FIELD — byzantine / channel /
    robust / quarantine / skew rejections all carry the field name and the
    bad value, and unsupported combinations (robust or per-commit fault
    families under by_unit aggregation, byzantine / channel / trimmed-mean
    under the async schedulers, skew vs noniid_s) are rejected by name;
  * the trim=0 static branch of the robust server IS the plain server —
    ``robust_aggregate_stacked_jnp(trim=0)`` returns bit-identical arrays
    to ``aggregate_by_worker_stacked_jnp``, ``clip=inf`` is a bit-exact
    no-op on deltas, and a run with ``faults=None`` + an all-inactive
    ``RobustAggConfig()`` is byte-identical to the pre-feature run;
  * Byzantine and lossy-channel worlds unfold identically under
    sequential, masked and fused engines: same fault ledgers (retries,
    byz / lost / dup / corrupt / quarantined commits), bit-identical
    clocks and prune indices, accuracy within 1e-3;
  * the MAD-outlier quarantine enters and exits on the documented
    schedule (strikes -> probation -> readmission), as a golden on
    ``health_step_jnp``;
  * trimmed-mean deduplicates by construction — duplicate delivery
    (multiplicity > 1) and payload values on zero-multiplicity rows
    cannot change the trimmed estimate;
  * ``ScenarioConfig.skew`` (Dirichlet label concentration) produces
    equal-size, disjoint, covering shards and keeps every engine
    bit-equivalent on the fault-free path;
  * the degenerate 1-device mesh runs the robust world bit-identically
    to the no-mesh fused engine (trimmed-mean all-gathers across the
    fleet axis), and async clip + quarantine agree across engines.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.aggregation import (
    QuarantineConfig,
    RobustAggConfig,
    aggregate_by_worker_stacked_jnp,
    clip_deltas_jnp,
    delta_norms_jnp,
    health_step_jnp,
    robust_aggregate_stacked_jnp,
    robust_submission_step_jnp,
)
from repro.core.faults import ByzantineConfig, ChannelConfig, FaultConfig
from repro.core.scenario import ScenarioConfig, ScenarioEngine
from repro.core.simulation import SimConfig, run_simulation
from repro.core.timing import HeterogeneityConfig
from repro.data.synthetic import partition_dirichlet
from repro.models.cnn import vgg_config

TINY = vgg_config("vgg_tiny_rb", [8, "M", 16], num_classes=4, image_size=8)

LEDGER_FIELDS = (
    "drift_events", "rounds_degraded", "rounds_skipped",
    "workers_recovered", "retry_total",
    "byz_commits", "lost_commits", "dup_commits", "corrupt_commits",
    "quarantined_commits",
)

BYZ = FaultConfig(byzantine=ByzantineConfig(
    workers=(0, 1), mode="scale", scale=-10.0))
CHAN = FaultConfig(channel=ChannelConfig(
    drop=0.2, dup=0.2, corrupt=0.1, corrupt_std=10.0))
# probation outlasts the 8-round runs: readmission cycling would put the
# exact-ledger engine contract one f32 ulp from a 3*MAD strike boundary
DEFENSE = RobustAggConfig(
    clip=5.0, trim=0.2, quarantine=QuarantineConfig(probation=100))


def _sim(engine, **kw):
    base = dict(
        method="adaptcl",
        engine=engine,
        rounds=8,
        prune_interval=2,
        num_workers=5,
        batch_size=16,
        cnn=TINY,
        het=HeterogeneityConfig(num_workers=5, sigma=3.0),
        eval_every=2,
        seed=5,
    )
    base.update(kw)
    return run_simulation(SimConfig(**base))


def _ledger(r):
    return {f: getattr(r, f) for f in LEDGER_FIELDS}


def _assert_engines_match(ref, other):
    assert abs(ref.final_acc - other.final_acc) <= 1e-3
    assert ref.prune_events == other.prune_events
    assert ref.scenario_rounds == other.scenario_rounds
    np.testing.assert_allclose(
        np.array(ref.update_times), np.array(other.update_times),
        rtol=0, atol=0, equal_nan=True,
    )
    assert ref.total_time == pytest.approx(other.total_time, abs=1e-9)
    assert ref.comm_bytes == pytest.approx(other.comm_bytes, abs=1e-6)
    assert _ledger(ref) == _ledger(other)


def _stacks(w=6, seed=0):
    rng = np.random.default_rng(seed)
    stacks = {
        "conv/w": jnp.asarray(rng.normal(0, 1, (w, 3, 4)).astype(np.float32)),
        "fc/w": jnp.asarray(rng.normal(0, 1, (w, 5)).astype(np.float32)),
    }
    masks = {
        k: jnp.asarray((rng.random(v.shape) > 0.3).astype(np.float32))
        for k, v in stacks.items()
    }
    return stacks, {k: stacks[k] * masks[k] for k in stacks}, masks


# ---------------------------------------------------------------------------
# config validation: rejections name the offending field
# ---------------------------------------------------------------------------

def test_robust_config_validation_names_fields():
    with pytest.raises(ValueError, match="byzantine workers"):
        ByzantineConfig(workers=())
    with pytest.raises(ValueError, match="byzantine fraction"):
        ByzantineConfig(fraction=1.5)
    with pytest.raises(ValueError, match="byzantine mode"):
        ByzantineConfig(fraction=0.1, mode="gaslight")
    with pytest.raises(ValueError, match="byzantine scale"):
        ByzantineConfig(fraction=0.1, mode="scale", scale=0.0)
    with pytest.raises(ValueError, match="byzantine noise_std"):
        ByzantineConfig(fraction=0.1, noise_std=0.0)
    with pytest.raises(ValueError, match="channel drop"):
        ChannelConfig(drop=1.0)
    with pytest.raises(ValueError, match="channel dup"):
        ChannelConfig(dup=-0.1)
    with pytest.raises(ValueError, match="channel corrupt"):
        ChannelConfig(corrupt=2.0)
    with pytest.raises(ValueError, match="channel max_retries"):
        ChannelConfig(drop=0.1, max_retries=-1)
    with pytest.raises(ValueError, match="channel retry_backoff"):
        ChannelConfig(drop=0.1, retry_backoff=-0.5)
    with pytest.raises(ValueError, match="channel corrupt_std"):
        ChannelConfig(corrupt=0.1, corrupt_std=0.0)
    with pytest.raises(ValueError, match="robust clip"):
        RobustAggConfig(clip=0.0)
    with pytest.raises(ValueError, match="robust trim"):
        RobustAggConfig(trim=0.5)
    with pytest.raises(ValueError, match="quarantine threshold"):
        QuarantineConfig(threshold=0.0)
    with pytest.raises(ValueError, match="quarantine strikes"):
        QuarantineConfig(strikes=0)
    with pytest.raises(ValueError, match="quarantine probation"):
        QuarantineConfig(probation=0)
    with pytest.raises(ValueError, match="scenario skew"):
        ScenarioEngine(ScenarioConfig(skew=0.0), 4)
    assert not RobustAggConfig().any_active
    assert RobustAggConfig(clip=1.0).any_active
    assert RobustAggConfig(trim=0.1).any_active
    assert RobustAggConfig(quarantine=QuarantineConfig()).any_active


def test_unsupported_combinations_rejected_by_name():
    with pytest.raises(ValueError, match="SimConfig.robust"):
        _sim("masked", aggregation="by_unit", robust=DEFENSE)
    with pytest.raises(ValueError, match="FaultConfig.byzantine"):
        _sim("masked", aggregation="by_unit",
             scenario=ScenarioConfig(faults=BYZ))
    with pytest.raises(ValueError, match="FaultConfig.channel"):
        _sim("masked", aggregation="by_unit",
             scenario=ScenarioConfig(faults=CHAN))
    with pytest.raises(ValueError, match="byzantine is sync-only"):
        _sim("masked", method="fedasync_s",
             scenario=ScenarioConfig(faults=BYZ))
    with pytest.raises(ValueError, match="channel is sync-only"):
        _sim("masked", method="fedasync_s",
             scenario=ScenarioConfig(faults=CHAN))
    with pytest.raises(ValueError, match=r"clip \+ quarantine only"):
        _sim("masked", method="fedasync_s",
             robust=RobustAggConfig(trim=0.2))
    with pytest.raises(ValueError, match="ScenarioConfig.skew"):
        _sim("masked", noniid_s=50.0, scenario=ScenarioConfig(skew=0.3))


# ---------------------------------------------------------------------------
# the trim=0 / clip=inf degenerate defenses are bit-exact no-ops
# ---------------------------------------------------------------------------

def test_trim0_is_plain_aggregation_bit_exact():
    _, stacks, masks = _stacks()
    w = jnp.asarray(np.float32([0.1, 0.3, 0.0, 0.2, 0.25, 0.15]))
    plain = aggregate_by_worker_stacked_jnp(stacks, w)
    robust = robust_aggregate_stacked_jnp(stacks, w, masks, trim=0.0)
    for k in plain:
        assert np.array_equal(np.asarray(plain[k]), np.asarray(robust[k]))


def test_clip_inf_is_a_bit_exact_noop():
    _, stacks, _ = _stacks()
    deltas = {k: v - 0.5 for k, v in stacks.items()}
    norms = delta_norms_jnp(deltas)
    clipped = clip_deltas_jnp(deltas, norms, float("inf"))
    for k in deltas:
        assert np.array_equal(np.asarray(deltas[k]), np.asarray(clipped[k]))
    # and a finite clip above every norm is equally untouched
    hi = float(np.asarray(norms).max()) * 2.0
    clipped = clip_deltas_jnp(deltas, norms, hi)
    for k in deltas:
        assert np.array_equal(np.asarray(deltas[k]), np.asarray(clipped[k]))


def test_defenseless_robust_step_is_plain_aggregation():
    _, stacks, masks = _stacks()
    w = jnp.asarray(np.full(6, 1.0 / 6.0, np.float32))
    mult = jnp.asarray(np.ones(6, np.float32))
    g = {k: jnp.zeros(v.shape[1:], v.dtype) for k, v in stacks.items()}
    plain = aggregate_by_worker_stacked_jnp(stacks, w)
    out, st, qu, quar_now = robust_submission_step_jnp(
        stacks, masks, g, mult, w, None, None, None, None, None, None,
        clip=None, trim=0.0, quarantine=None)
    assert st is None and qu is None and quar_now is None
    for k in plain:
        assert np.array_equal(np.asarray(plain[k]), np.asarray(out[k]))


def test_inactive_robust_config_bit_identical_to_pre_feature():
    """``faults=None`` + all-inactive robust/fault configs consume zero RNG
    and route the pre-feature aggregation path, byte for byte."""
    ref = _sim("masked", rounds=4)
    inert = _sim("masked", rounds=4, robust=RobustAggConfig(),
                 scenario=ScenarioConfig(faults=FaultConfig()))
    assert inert.final_acc == ref.final_acc
    assert inert.prune_events == ref.prune_events
    assert inert.total_time == ref.total_time
    assert inert.update_times == ref.update_times
    for k in ref.global_params:
        assert np.array_equal(ref.global_params[k], inert.global_params[k])
    assert _ledger(ref) == _ledger(inert) == {f: 0 for f in LEDGER_FIELDS}


# ---------------------------------------------------------------------------
# engine equivalence under attack
# ---------------------------------------------------------------------------

def test_byzantine_world_engines_match():
    seq = _sim("sequential", scenario=ScenarioConfig(faults=BYZ),
               robust=DEFENSE)
    mas = _sim("masked", scenario=ScenarioConfig(faults=BYZ), robust=DEFENSE)
    fus = _sim("fused", scenario=ScenarioConfig(faults=BYZ), robust=DEFENSE)
    _assert_engines_match(seq, mas)
    _assert_engines_match(mas, fus)
    assert mas.byz_commits > 0
    assert fus.recompiles <= 2


def test_channel_world_engines_match():
    mas = _sim("masked", scenario=ScenarioConfig(faults=CHAN), robust=DEFENSE)
    fus = _sim("fused", scenario=ScenarioConfig(faults=CHAN), robust=DEFENSE)
    _assert_engines_match(mas, fus)
    assert mas.retry_total > 0 or mas.dup_commits > 0 or mas.lost_commits > 0
    assert fus.recompiles <= 2


# ---------------------------------------------------------------------------
# quarantine schedule golden (health_step_jnp)
# ---------------------------------------------------------------------------

def test_quarantine_enter_exit_schedule():
    """Worker 0's norm is a 10x MAD outlier every round it is eligible:
    2 strikes -> 3 probation rounds out -> readmitted -> re-struck."""
    W = 5
    strikes = jnp.zeros(W, jnp.int32)
    quar = jnp.zeros(W, jnp.int32)
    norms = jnp.asarray(np.float32([10.0, 1.0, 1.0, 1.0, 1.0]))
    elig = jnp.asarray(np.ones(W, bool))
    seen = []
    for _ in range(8):
        quar_now, strikes, quar = health_step_jnp(
            norms, elig, strikes, quar,
            threshold=3.0, strikes_needed=2, probation=3)
        seen.append(bool(np.asarray(quar_now)[0]))
        assert not np.asarray(quar_now)[1:].any()
    # rounds 0-1 striking, 2-4 quarantined, 5-6 striking again, 7 back in
    assert seen == [False, False, True, True, True, False, False, True]


def test_quarantine_gate_freezes_state():
    W = 3
    strikes = jnp.asarray(np.int32([1, 0, 0]))
    quar = jnp.asarray(np.int32([0, 2, 0]))
    norms = jnp.asarray(np.float32([50.0, 1.0, 1.0]))
    elig = jnp.asarray(np.ones(W, bool))
    _, st2, qu2 = health_step_jnp(
        norms, elig, strikes, quar,
        threshold=3.0, strikes_needed=2, probation=3,
        gate=jnp.asarray(False))
    assert np.array_equal(np.asarray(st2), np.asarray(strikes))
    assert np.array_equal(np.asarray(qu2), np.asarray(quar))


# ---------------------------------------------------------------------------
# duplicate / lost commits vs the trimmed estimate
# ---------------------------------------------------------------------------

def test_trimmed_mean_ignores_duplicate_multiplicity():
    """A duplicated delivery is ONE vote: multiplicity scales the plain
    mean's weights but cannot change the trimmed order statistics."""
    _, stacks, masks = _stacks()
    g = {k: jnp.zeros(v.shape[1:], v.dtype) for k, v in stacks.items()}
    once = jnp.asarray(np.float32([1, 1, 1, 1, 1, 1]))
    duped = jnp.asarray(np.float32([2, 1, 1, 3, 1, 1]))
    out1, *_ = robust_submission_step_jnp(
        stacks, masks, g, once, once / once.sum(), None, None, None, None,
        None, None, clip=None, trim=0.2, quarantine=None)
    out2, *_ = robust_submission_step_jnp(
        stacks, masks, g, duped, duped / duped.sum(), None, None, None, None,
        None, None, clip=None, trim=0.2, quarantine=None)
    for k in out1:
        assert np.array_equal(np.asarray(out1[k]), np.asarray(out2[k]))


def test_lost_commit_payload_cannot_vote():
    """A zero-multiplicity (lost) row's payload values never reach the
    trimmed estimate — garbage in the dropped row changes nothing."""
    _, stacks, masks = _stacks()
    g = {k: jnp.zeros(v.shape[1:], v.dtype) for k, v in stacks.items()}
    mult = jnp.asarray(np.float32([0, 1, 1, 1, 1, 1]))
    w = mult / mult.sum()
    garbled = {
        k: v.at[0].set(jnp.full(v.shape[1:], 1e9, v.dtype))
        for k, v in stacks.items()
    }
    out1, *_ = robust_submission_step_jnp(
        stacks, masks, g, mult, w, None, None, None, None, None, None,
        clip=None, trim=0.2, quarantine=None)
    out2, *_ = robust_submission_step_jnp(
        garbled, masks, g, mult, w, None, None, None, None, None, None,
        clip=None, trim=0.2, quarantine=None)
    for k in out1:
        assert np.array_equal(np.asarray(out1[k]), np.asarray(out2[k]))


# ---------------------------------------------------------------------------
# Dirichlet shard skew (ScenarioConfig.skew)
# ---------------------------------------------------------------------------

def test_partition_dirichlet_properties():
    rng = np.random.default_rng(0)
    y = rng.integers(0, 4, 120)
    shards = partition_dirichlet(y, 5, alpha=0.2, seed=3)
    assert len(shards) == 5
    assert all(len(s) == 24 for s in shards)
    allidx = np.concatenate(shards)
    assert len(np.unique(allidx)) == 120          # disjoint and covering
    again = partition_dirichlet(y, 5, alpha=0.2, seed=3)
    assert all(np.array_equal(a, b) for a, b in zip(shards, again))
    # small alpha concentrates labels: some shard is dominated by one class
    shares = [
        np.bincount(y[s], minlength=4).max() / len(s) for s in shards
    ]
    assert max(shares) > 0.5


def test_skew_engines_match():
    scen = ScenarioConfig(skew=0.3)
    seq = _sim("sequential", scenario=scen)
    mas = _sim("masked", scenario=scen)
    fus = _sim("fused", scenario=scen)
    _assert_engines_match(seq, mas)
    _assert_engines_match(mas, fus)


# ---------------------------------------------------------------------------
# mesh + async legs
# ---------------------------------------------------------------------------

def test_one_device_mesh_robust_world_bit_identical(eight_devices):
    from repro.launch.mesh import make_fleet_mesh

    kw = dict(scenario=ScenarioConfig(faults=BYZ), robust=DEFENSE,
              num_workers=8, het=HeterogeneityConfig(num_workers=8, sigma=3.0),
              rounds=6)
    ref = _sim("fused", **kw)
    one = _sim("fused", mesh=make_fleet_mesh(1), **kw)
    for k in ref.global_params:
        assert np.array_equal(ref.global_params[k], one.global_params[k])
    _assert_engines_match(ref, one)


@pytest.mark.slow
def test_sharded_mesh_robust_world_matches(eight_devices):
    from repro.launch.mesh import make_fleet_mesh

    kw = dict(scenario=ScenarioConfig(faults=BYZ), robust=DEFENSE,
              num_workers=8, het=HeterogeneityConfig(num_workers=8, sigma=3.0),
              rounds=6)
    ref = _sim("fused", **kw)
    shd = _sim("fused", mesh=make_fleet_mesh(4), **kw)
    _assert_engines_match(ref, shd)


def test_async_clip_quarantine_engines_agree():
    rb = RobustAggConfig(
        clip=0.5,
        quarantine=QuarantineConfig(threshold=1.0, strikes=1, probation=2))
    mas = _sim("masked", method="fedasync_s", robust=rb)
    fus = _sim("fused", method="fedasync_s", robust=rb)
    assert mas.quarantined_commits == fus.quarantined_commits
    # masked commits in host f64, the fused scan in device f32: the reject
    # schedule and clocks are exact, accuracy may drift a test image or two
    assert abs(mas.final_acc - fus.final_acc) <= 0.01
    assert mas.total_time == pytest.approx(fus.total_time, abs=1e-9)
    assert mas.quarantined_commits > 0
