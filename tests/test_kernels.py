"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype swept."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels.ref import flash_attention_ref, pruned_matmul_ref, rg_lru_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "M,K,N,keep_k,keep_n",
    [
        (128, 256, 128, 256, 128),      # nothing pruned
        (256, 512, 384, 300, 200),      # CIG prefix pruning
        (128, 384, 256, 128, 64),       # heavy pruning (blocks skipped)
        (128, 256, 128, 1, 1),          # extreme
    ],
)
def test_pruned_matmul(dtype, M, K, N, keep_k, keep_n):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (M, K), jnp.float32).astype(dtype)
    w = (jax.random.normal(ks[1], (K, N), jnp.float32) * 0.05).astype(dtype)
    in_mask = np.zeros(K, np.float32)
    in_mask[:keep_k] = 1
    out_mask = np.zeros(N, np.float32)
    out_mask[:keep_n] = 1
    y = ops.pruned_matmul(x, w, jnp.asarray(in_mask), jnp.asarray(out_mask))
    ref = pruned_matmul_ref(x, w, jnp.arange(keep_k), jnp.arange(keep_n))
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(y[:, :keep_n], np.float32), np.asarray(ref, np.float32),
        atol=tol, rtol=tol,
    )
    if keep_n < N:
        assert np.abs(np.asarray(y[:, keep_n:], np.float32)).max() == 0.0


@pytest.mark.parametrize("M,K,N", [(200, 300, 130), (1, 1, 1), (100, 128, 129)])
def test_pruned_matmul_ragged_shapes(M, K, N):
    """Non-128-multiple dims are padded to block multiples and sliced back;
    padded mask entries are zero, so the padding blocks are skipped."""
    rng = np.random.default_rng(M)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)) * 0.05, jnp.float32)
    in_mask = (rng.random(K) < 0.7).astype(np.float32)
    out_mask = (rng.random(N) < 0.7).astype(np.float32)
    in_mask[0] = out_mask[0] = 1.0
    y = ops.pruned_matmul(x, w, jnp.asarray(in_mask), jnp.asarray(out_mask))
    dense = (x * in_mask[None, :]) @ w * out_mask[None, :]
    assert y.shape == (M, N)
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense), atol=1e-4, rtol=1e-4)


def test_pruned_matmul_row_mask():
    """The optional row mask zeroes (and block-skips) masked M rows."""
    rng = np.random.default_rng(5)
    M, K, N = 160, 128, 128
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)) * 0.05, jnp.float32)
    ones_k, ones_n = jnp.ones(K, jnp.float32), jnp.ones(N, jnp.float32)
    row = np.zeros(M, np.float32)
    row[:50] = 1.0
    y = ops.pruned_matmul(x, w, ones_k, ones_n, jnp.asarray(row))
    dense = (x @ w) * row[:, None]
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense), atol=1e-4, rtol=1e-4)
    assert np.abs(np.asarray(y)[50:]).max() == 0.0


def test_pruned_matmul_random_mask():
    """Non-prefix (scattered) retained sets are also exact."""
    rng = np.random.default_rng(0)
    K, N = 384, 256
    x = jnp.asarray(rng.normal(size=(128, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)) * 0.05, jnp.float32)
    in_mask = (rng.random(K) < 0.6).astype(np.float32)
    out_mask = (rng.random(N) < 0.5).astype(np.float32)
    y = ops.pruned_matmul(x, w, jnp.asarray(in_mask), jnp.asarray(out_mask))
    dense = (x * in_mask[None, :]) @ w * out_mask[None, :]
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,d,kw",
    [
        (2, 256, 4, 64, {}),
        (1, 256, 2, 128, {"window": 64}),
        (2, 128, 2, 64, {"softcap": 50.0}),
        (1, 256, 2, 64, {"causal": False}),
        (1, 512, 1, 64, {"window": 100, "softcap": 30.0}),
    ],
)
def test_flash_attention(dtype, b, s, h, d, kw):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, h, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, d), jnp.float32).astype(dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_kv=64, **kw)
    ref = flash_attention_ref(q, k, v, **kw)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("blocks", [(4, 128, 128), (8, 256, 128), (2, 64, 256)])
def test_rg_lru_scan(blocks):
    bb, bs, bc = blocks
    b, s, r = 8, 512, 256
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (b, s, r), jnp.float32) * 0.1
    a = jax.random.uniform(ks[1], (b, s, r), jnp.float32, 0.85, 0.999)
    h0 = jax.random.normal(ks[2], (b, r), jnp.float32) * 0.1
    out = ops.rg_lru_scan(x, a, h0, block_b=bb, block_s=bs, block_c=bc)
    ref = rg_lru_ref(x, a, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4, rtol=2e-4)


@pytest.mark.slow
def test_rg_lru_matches_model_recurrence():
    """The kernel computes the same recurrence the RG-LRU block uses."""
    from repro.models.rglru import RGLRUSpec, init_rglru, rglru_fwd

    spec = RGLRUSpec(d_model=64, d_rnn=128, num_heads=4)
    p = init_rglru(jax.random.PRNGKey(3), spec)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 128, 64)) * 0.3
    out_model, state = rglru_fwd(p, spec, x)
    assert np.isfinite(np.asarray(out_model)).all()
