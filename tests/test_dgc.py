"""Unit tests for the DGC delta compressors (`simulation._dgc_compress` and
the vectorized `_dgc_compress_stacked` the resident engine uses)."""
import numpy as np
import pytest

from repro.core.simulation import _dgc_compress, _dgc_compress_stacked


def _delta(rng, shapes):
    return {k: rng.normal(size=s).astype(np.float32) for k, s in shapes.items()}


SHAPES = {"a/w": (3, 3, 2, 4), "b/w": (8,)}


def test_committed_plus_residual_is_accumulated_delta():
    rng = np.random.default_rng(0)
    delta = _delta(rng, SHAPES)
    residual = _delta(rng, SHAPES)
    committed, new_res, _ = _dgc_compress(delta, residual, 0.7)
    for k in delta:
        acc = delta[k] + residual[k]
        np.testing.assert_allclose(committed[k] + new_res[k], acc, atol=1e-6)
        # committed entries are exactly the largest-|.| entries of acc
        assert np.count_nonzero(new_res[k] * committed[k]) == 0


def test_payload_factor_bounds():
    rng = np.random.default_rng(1)
    delta = _delta(rng, SHAPES)
    for sparsity in (0.0, 0.5, 0.9, 0.999):
        _, _, factor = _dgc_compress(delta, {}, sparsity)
        assert 0.0 < factor <= 1.25
    # denser commits cost more
    f_low = _dgc_compress(delta, {}, 0.9)[2]
    f_high = _dgc_compress(delta, {}, 0.5)[2]
    assert f_low < f_high


def test_shape_change_drops_residual():
    rng = np.random.default_rng(2)
    delta = _delta(rng, SHAPES)
    # a reconfiguration shrank "b/w": stale residual must be ignored
    residual = {"b/w": rng.normal(size=(16,)).astype(np.float32)}
    committed, new_res, _ = _dgc_compress(delta, residual, 0.5)
    for k in delta:
        np.testing.assert_allclose(committed[k] + new_res[k], delta[k], atol=1e-6)


def test_zero_sparsity_commits_everything():
    rng = np.random.default_rng(3)
    delta = _delta(rng, SHAPES)
    committed, new_res, factor = _dgc_compress(delta, {}, 0.0)
    for k in delta:
        np.testing.assert_allclose(committed[k], delta[k])
        assert not new_res[k].any()
    assert factor == pytest.approx(1.25)


def test_shape_change_resets_kept_fraction_accounting():
    """A reconfigured tensor restarts DGC: dense warm-up commit, and the
    payload factor counts the WHOLE tensor as kept that round."""
    rng = np.random.default_rng(4)
    delta = _delta(rng, SHAPES)        # a/w: 72 entries, b/w: 8 entries
    residual = {"b/w": rng.normal(size=(16,)).astype(np.float32)}   # stale shape
    committed, new_res, factor = _dgc_compress(delta, residual, 0.5)
    np.testing.assert_allclose(committed["b/w"], delta["b/w"])      # dense
    assert not new_res["b/w"].any()
    kept = round(72 * 0.5) + 8         # sparse a/w + dense-restarted b/w
    assert factor == pytest.approx(1.25 * kept / 80)


# ---------------------------------------------------------------------------
# stacked (resident [W, ...]) path
# ---------------------------------------------------------------------------

def _stack(rng, W, shapes):
    return {k: rng.normal(size=(W,) + s).astype(np.float32) for k, s in shapes.items()}


@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.9])
def test_stacked_matches_per_worker(sparsity):
    rng = np.random.default_rng(5)
    W = 4
    delta = _stack(rng, W, SHAPES)
    residual = _stack(rng, W, SHAPES)
    committed, new_res, factors = _dgc_compress_stacked(delta, residual, sparsity)
    for w in range(W):
        c_ref, r_ref, f_ref = _dgc_compress(
            {k: v[w] for k, v in delta.items()},
            {k: v[w] for k, v in residual.items()},
            sparsity,
        )
        for k in delta:
            np.testing.assert_allclose(committed[k][w], c_ref[k], atol=1e-6)
            np.testing.assert_allclose(new_res[k][w], r_ref[k], atol=1e-6)
        assert factors[w] == pytest.approx(f_ref)


def test_stacked_mask_awareness():
    """With 0/1 masks, the keep budget is a fraction of each worker's RETAINED
    coordinates (matching the per-worker compressor on the reconfigured
    tensor); pruned coordinates are never committed nor kept as residual."""
    rng = np.random.default_rng(6)
    W = 3
    shapes = {"w": (8,)}
    delta = _stack(rng, W, shapes)
    masks = {"w": np.ones((W, 8), np.float32)}
    masks["w"][1, 4:] = 0.0                        # worker 1 retains 4 coords
    delta["w"] *= masks["w"]
    committed, new_res, factors = _dgc_compress_stacked(
        delta, {k: np.zeros_like(v) for k, v in delta.items()}, 0.5, masks=masks
    )
    assert not (committed["w"][1, 4:]).any()
    assert not (new_res["w"][1, 4:]).any()
    np.testing.assert_allclose(
        committed["w"][1] + new_res["w"][1], delta["w"][1], atol=1e-6
    )
    # worker 1's budget: round(4 * 0.5) = 2 of its 4 retained coordinates
    assert np.count_nonzero(committed["w"][1]) == 2
    assert factors[1] == pytest.approx(1.25 * 2 / 4)
    # full-mask workers keep round(8 * 0.5) = 4
    assert factors[0] == pytest.approx(1.25 * 4 / 8)


@pytest.mark.parametrize("sparsity", [0.3, 0.5, 0.9])
def test_realized_kept_counts_on_threshold_ties(sparsity):
    """Payload accounting property: the reported factor counts the REALIZED
    commits.  Quantized |delta| values collide massively at the threshold,
    and ties all pass the >= test — so the factor must equal
    1.25 * nnz(committed) / total, never the nominal keep budget."""
    rng = np.random.default_rng(8)
    W = 3
    delta = {
        "w": rng.choice([-2.0, -1.0, 1.0, 2.0], size=(W, 16)).astype(np.float32)
    }
    zeros = {k: np.zeros_like(v) for k, v in delta.items()}
    committed, _, factors = _dgc_compress_stacked(delta, zeros, sparsity)
    for w in range(W):
        nnz = np.count_nonzero(committed["w"][w])
        assert factors[w] == pytest.approx(1.25 * nnz / 16)
        # the per-worker compressor reports the same realized count
        c_ref, _, f_ref = _dgc_compress({"w": delta["w"][w]}, {}, sparsity)
        assert np.count_nonzero(c_ref["w"]) == nnz
        assert f_ref == pytest.approx(factors[w])


def test_fully_masked_row_commits_nothing():
    """A worker whose mask is all-zero for a tensor has keep budget 0 there —
    the threshold sentinel (-1) must not let anything through."""
    rng = np.random.default_rng(9)
    W = 2
    delta = {"w": rng.normal(size=(W, 8)).astype(np.float32)}
    masks = {"w": np.ones((W, 8), np.float32)}
    masks["w"][1] = 0.0
    zeros = {k: np.zeros_like(v) for k, v in delta.items()}
    committed, new_res, factors = _dgc_compress_stacked(
        delta, zeros, 0.5, masks=masks
    )
    assert not committed["w"][1].any()
    assert not new_res["w"][1].any()
    assert factors[0] > 0.0


def test_device_compressor_bit_identical_to_host():
    """aggregation.dgc_compress_jnp vs _dgc_compress_stacked: identical f32
    keep budgets + thresholds-by-value mean the keep SETS are bit-identical,
    even under adversarial |delta| ties, masks, and row gating — the same
    contract that makes device pruning host-exact."""
    from repro.core.aggregation import dgc_compress_jnp
    import jax.numpy as jnp

    rng = np.random.default_rng(10)
    W = 4
    delta = {
        # quantized values: massive tie collisions at any threshold
        "a/w": rng.choice([-2.0, -1.0, 0.5, 1.0, 2.0], size=(W, 3, 3, 2, 4))
        .astype(np.float32),
        "b/w": rng.normal(size=(W, 8)).astype(np.float32),
    }
    residual = {k: rng.normal(size=v.shape).astype(np.float32) * 0.1
                for k, v in delta.items()}
    masks = {k: (rng.random(v.shape) < 0.7).astype(np.float32)
             for k, v in delta.items()}
    masks["b/w"][2] = 0.0                       # one fully-masked row
    rows = np.array([True, True, False, True])

    for sparsity in (0.3, 0.7, 0.95):
        c_h, r_h, factors = _dgc_compress_stacked(
            delta, residual, sparsity, masks=masks, rows=rows
        )
        c_d, r_d, kept, total = dgc_compress_jnp(
            {k: jnp.asarray(v) for k, v in delta.items()},
            {k: jnp.asarray(v) for k, v in residual.items()},
            sparsity,
            {k: jnp.asarray(v) for k, v in masks.items()},
            jnp.asarray(rows),
        )
        kept, total = np.asarray(kept), np.asarray(total)
        for k in delta:
            np.testing.assert_array_equal(c_h[k], np.asarray(c_d[k]),
                                          err_msg=f"committed {k} s={sparsity}")
            np.testing.assert_array_equal(r_h[k], np.asarray(r_d[k]),
                                          err_msg=f"residual {k} s={sparsity}")
        # realized counts rebuild the host factors exactly
        np.testing.assert_allclose(
            np.where(rows, 1.25 * kept / np.maximum(total, 1), 1.0),
            factors, rtol=0, atol=0,
        )


def test_stacked_rows_gate_commits():
    """Non-submitting rows commit nothing and keep their residual untouched."""
    rng = np.random.default_rng(7)
    W = 3
    delta = _stack(rng, W, SHAPES)
    residual = _stack(rng, W, SHAPES)
    rows = np.array([True, False, True])
    committed, new_res, factors = _dgc_compress_stacked(
        delta, residual, 0.5, rows=rows
    )
    for k in SHAPES:
        assert not committed[k][1].any()
        np.testing.assert_allclose(new_res[k][1], residual[k][1])
    assert factors[1] == pytest.approx(1.0)
