"""Unit tests for the DGC delta compressor (`simulation._dgc_compress`)."""
import numpy as np
import pytest

from repro.core.simulation import _dgc_compress


def _delta(rng, shapes):
    return {k: rng.normal(size=s).astype(np.float32) for k, s in shapes.items()}


SHAPES = {"a/w": (3, 3, 2, 4), "b/w": (8,)}


def test_committed_plus_residual_is_accumulated_delta():
    rng = np.random.default_rng(0)
    delta = _delta(rng, SHAPES)
    residual = _delta(rng, SHAPES)
    committed, new_res, _ = _dgc_compress(delta, residual, 0.7)
    for k in delta:
        acc = delta[k] + residual[k]
        np.testing.assert_allclose(committed[k] + new_res[k], acc, atol=1e-6)
        # committed entries are exactly the largest-|.| entries of acc
        assert np.count_nonzero(new_res[k] * committed[k]) == 0


def test_payload_factor_bounds():
    rng = np.random.default_rng(1)
    delta = _delta(rng, SHAPES)
    for sparsity in (0.0, 0.5, 0.9, 0.999):
        _, _, factor = _dgc_compress(delta, {}, sparsity)
        assert 0.0 < factor <= 1.25
    # denser commits cost more
    f_low = _dgc_compress(delta, {}, 0.9)[2]
    f_high = _dgc_compress(delta, {}, 0.5)[2]
    assert f_low < f_high


def test_shape_change_drops_residual():
    rng = np.random.default_rng(2)
    delta = _delta(rng, SHAPES)
    # a reconfiguration shrank "b/w": stale residual must be ignored
    residual = {"b/w": rng.normal(size=(16,)).astype(np.float32)}
    committed, new_res, _ = _dgc_compress(delta, residual, 0.5)
    for k in delta:
        np.testing.assert_allclose(committed[k] + new_res[k], delta[k], atol=1e-6)


def test_zero_sparsity_commits_everything():
    rng = np.random.default_rng(3)
    delta = _delta(rng, SHAPES)
    committed, new_res, factor = _dgc_compress(delta, {}, 0.0)
    for k in delta:
        np.testing.assert_allclose(committed[k], delta[k])
        assert not new_res[k].any()
    assert factor == pytest.approx(1.25)
