"""Distributed-pruning principles: CIG nesting, similarity, budgets (§III-D)."""
import numpy as np
import pytest

from repro.core.importance import CIG_METHODS, METHODS, ImportanceContext
from repro.core.masks import (
    UnitLayer,
    UnitSpace,
    full_index,
    is_nested,
    payload_bytes,
    prune_to_budget,
    retention,
    similarity,
)

SPACE = UnitSpace(
    layers=(
        UnitLayer("a", 32, 100),
        UnitLayer("b", 64, 50),
        UnitLayer("c", 16, 200),
    ),
    fixed_params=1000,
)


def _ctx(worker=0, rnd=0, seed=7):
    rng = np.random.default_rng(3)
    return ImportanceContext(
        unit_counts=SPACE.unit_counts,
        scales={k: rng.random(n) for k, n in SPACE.unit_counts.items()},
        weight_norms={k: rng.random(n) for k, n in SPACE.unit_counts.items()},
        worker=worker,
        round=rnd,
        seed=seed,
    )


def test_budget_accuracy():
    idx = full_index(SPACE)
    scores = METHODS["index"](_ctx())
    for rate in (0.1, 0.3, 0.5, 0.7):
        out = prune_to_budget(idx, scores, rate, SPACE)
        achieved = 1.0 - retention(out, SPACE) / retention(idx, SPACE)
        # greedy block cutting overshoots by at most one max-cost unit
        assert rate - 1e-9 <= achieved <= rate + 200 / SPACE.total_params + 1e-9


def test_cig_methods_nest_across_workers_and_rounds():
    """Identical+Constant criteria guarantee I_small ⊂ I_big (paper's key)."""
    for name in CIG_METHODS:
        indices = []
        for worker, rate_seq in enumerate([(0.2, 0.3), (0.5,), (0.1, 0.2, 0.4)]):
            idx = full_index(SPACE)
            for rnd, rate in enumerate(rate_seq):
                scores = METHODS[name](_ctx(worker, rnd))
                idx = prune_to_budget(idx, scores, rate, SPACE)
            indices.append(idx)
        # sort by retention; every smaller sub-model must nest in every bigger
        indices.sort(key=lambda i: retention(i, SPACE))
        for small, big in zip(indices, indices[1:]):
            assert is_nested(small, big), f"{name} violated nesting"


def test_no_identical_breaks_nesting():
    ia = prune_to_budget(full_index(SPACE), METHODS["no_identical"](_ctx(worker=0)), 0.5, SPACE)
    ib = prune_to_budget(full_index(SPACE), METHODS["no_identical"](_ctx(worker=1)), 0.2, SPACE)
    assert not is_nested(ia, ib)
    assert similarity(ia, ib) < 0.9


def test_no_constant_changes_over_rounds():
    s0 = METHODS["no_constant"](_ctx(rnd=0))
    s1 = METHODS["no_constant"](_ctx(rnd=1))
    assert any(not np.array_equal(s0[k], s1[k]) for k in s0)


def test_similarity_eq3():
    i1 = {"a": np.array([0, 1, 2, 3]), "b": np.array([0, 1])}
    i2 = {"a": np.array([2, 3, 4, 5]), "b": np.array([0, 1])}
    # layer a: |{2,3}|/|{0..5}| = 2/6; layer b: 2/2
    assert abs(similarity(i1, i2) - (2 / 6 + 1.0) / 2) < 1e-12
    assert similarity(i1, i1) == 1.0


def test_min_units_respected():
    idx = full_index(SPACE)
    scores = METHODS["index"](_ctx())
    out = prune_to_budget(idx, scores, 0.7, SPACE)
    for l in SPACE.layers:
        assert len(out[l.name]) >= l.min_units


def test_payload_counts_index_overhead():
    idx = full_index(SPACE)
    base = payload_bytes(idx, SPACE)
    assert base > SPACE.total_params * 4  # params + index ids
